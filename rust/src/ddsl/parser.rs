//! DDSL recursive-descent parser: tokens → [`Program`].

use super::ast::*;
use super::lexer::{Token, TokenKind};
use crate::{Error, Result};

pub fn parse(tokens: &[Token]) -> Result<Program> {
    let mut p = Parser { tokens, pos: 0 };
    let mut program = Program::default();
    while !p.at_end() {
        if p.peek_ident("DVar") || p.peek_ident("DSet") {
            program.decls.push(p.decl()?);
        } else {
            program.body.push(p.stmt()?);
        }
    }
    Ok(program)
}

struct Parser<'a> {
    tokens: &'a [Token],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn at_end(&self) -> bool {
        self.pos >= self.tokens.len()
    }

    fn line(&self) -> usize {
        self.tokens
            .get(self.pos.min(self.tokens.len().saturating_sub(1)))
            .map(|t| t.line)
            .unwrap_or(0)
    }

    fn err(&self, msg: &str) -> Error {
        Error::Ddsl(format!("{msg} (line {})", self.line()))
    }

    fn peek(&self) -> Option<&TokenKind> {
        self.tokens.get(self.pos).map(|t| &t.kind)
    }

    fn peek_ident(&self, word: &str) -> bool {
        matches!(self.peek(), Some(TokenKind::Ident(s)) if s == word)
    }

    /// Advance and return an owned copy of the token (owned so error
    /// paths can re-borrow `self` for diagnostics).
    fn bump(&mut self) -> Option<TokenKind> {
        let t = self.tokens.get(self.pos).map(|t| t.kind.clone());
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn expect(&mut self, kind: &TokenKind, what: &str) -> Result<()> {
        if self.peek() == Some(kind) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {what}, found {:?}", self.peek())))
        }
    }

    fn ident(&mut self, what: &str) -> Result<String> {
        match self.bump() {
            Some(TokenKind::Ident(s)) => Ok(s.clone()),
            other => Err(self.err(&format!("expected {what}, found {other:?}"))),
        }
    }

    fn size_expr(&mut self, what: &str) -> Result<SizeExpr> {
        match self.bump() {
            Some(TokenKind::Number(n)) if n >= 0.0 && n.fract() == 0.0 => {
                Ok(SizeExpr::Lit(n as usize))
            }
            Some(TokenKind::Ident(s)) => Ok(SizeExpr::Var(s.clone())),
            other => Err(self.err(&format!("expected {what}, found {other:?}"))),
        }
    }

    fn decl(&mut self) -> Result<Decl> {
        let kw = self.ident("declaration keyword")?;
        match kw.as_str() {
            "DVar" => {
                let name = self.ident("variable name")?;
                let ty_name = self.ident("type")?;
                let ty = DType::parse(&ty_name)
                    .ok_or_else(|| self.err(&format!("unknown type {ty_name:?}")))?;
                let init = match self.peek() {
                    Some(TokenKind::Number(n)) => {
                        let v = Value::Num(*n);
                        self.pos += 1;
                        Some(v)
                    }
                    Some(TokenKind::Bool(b)) => {
                        let v = Value::Bool(*b);
                        self.pos += 1;
                        Some(v)
                    }
                    _ => None,
                };
                self.expect(&TokenKind::Semi, "';'")?;
                Ok(Decl::Var { name, ty, init })
            }
            "DSet" => {
                let name = self.ident("set name")?;
                let ty_name = self.ident("type")?;
                let ty = DType::parse(&ty_name)
                    .ok_or_else(|| self.err(&format!("unknown type {ty_name:?}")))?;
                let size = self.size_expr("set size")?;
                let dim = self.size_expr("set dimension")?;
                self.expect(&TokenKind::Semi, "';'")?;
                Ok(Decl::Set { name, ty, size, dim })
            }
            other => Err(self.err(&format!("unknown declaration {other:?}"))),
        }
    }

    fn stmt(&mut self) -> Result<Stmt> {
        match self.peek() {
            Some(TokenKind::Ident(s)) => match s.as_str() {
                "AccD_Comp_Dist" => self.comp_dist(),
                "AccD_Dist_Select" => self.dist_select(),
                "AccD_Update" => self.update(),
                "AccD_Iter" => self.iter(),
                _ => self.assign(),
            },
            other => Err(self.err(&format!("expected statement, found {other:?}"))),
        }
    }

    fn comp_dist(&mut self) -> Result<Stmt> {
        self.ident("AccD_Comp_Dist")?;
        self.expect(&TokenKind::LParen, "'('")?;
        let src = self.ident("source set")?;
        self.expect(&TokenKind::Comma, "','")?;
        let trg = self.ident("target set")?;
        self.expect(&TokenKind::Comma, "','")?;
        let dist_mat = self.ident("distance matrix")?;
        self.expect(&TokenKind::Comma, "','")?;
        let id_mat = self.ident("id matrix")?;
        self.expect(&TokenKind::Comma, "','")?;
        let dim = self.size_expr("dimension")?;
        self.expect(&TokenKind::Comma, "','")?;
        let metric = match self.bump() {
            Some(TokenKind::Str(s)) => Metric::parse(&s)
                .ok_or_else(|| Error::Ddsl(format!("unknown metric {s:?}")))?,
            other => return Err(self.err(&format!("expected metric string, found {other:?}"))),
        };
        self.expect(&TokenKind::Comma, "','")?;
        let weight = match self.bump() {
            Some(TokenKind::Number(n)) if n == 0.0 => None,
            Some(TokenKind::Ident(s)) => Some(s.clone()),
            other => return Err(self.err(&format!("expected weight matrix or 0, found {other:?}"))),
        };
        self.expect(&TokenKind::RParen, "')'")?;
        self.expect(&TokenKind::Semi, "';'")?;
        Ok(Stmt::CompDist { src, trg, dist_mat, id_mat, dim, metric, weight })
    }

    fn dist_select(&mut self) -> Result<Stmt> {
        self.ident("AccD_Dist_Select")?;
        self.expect(&TokenKind::LParen, "'('")?;
        let dist_mat = self.ident("distance matrix")?;
        self.expect(&TokenKind::Comma, "','")?;
        let id_mat = self.ident("id matrix")?;
        self.expect(&TokenKind::Comma, "','")?;
        let range = self.size_expr("range (K or threshold)")?;
        self.expect(&TokenKind::Comma, "','")?;
        let scope = match self.bump() {
            Some(TokenKind::Str(s)) => s.clone(),
            other => return Err(self.err(&format!("expected scope string, found {other:?}"))),
        };
        self.expect(&TokenKind::Comma, "','")?;
        let out_mat = self.ident("output matrix")?;
        self.expect(&TokenKind::RParen, "')'")?;
        self.expect(&TokenKind::Semi, "';'")?;
        if !["smallest", "largest", "within"].contains(&scope.as_str()) {
            return Err(Error::Ddsl(format!("unknown selection scope {scope:?}")));
        }
        Ok(Stmt::DistSelect { dist_mat, id_mat, range, scope, out_mat })
    }

    fn update(&mut self) -> Result<Stmt> {
        self.ident("AccD_Update")?;
        self.expect(&TokenKind::LParen, "'('")?;
        let mut names = vec![self.ident("update target")?];
        while self.peek() == Some(&TokenKind::Comma) {
            self.pos += 1;
            names.push(self.ident("update argument")?);
        }
        self.expect(&TokenKind::RParen, "')'")?;
        // Paper's example omits the trailing semicolon on AccD_Update;
        // accept both.
        if self.peek() == Some(&TokenKind::Semi) {
            self.pos += 1;
        }
        if names.len() < 2 {
            return Err(self.err("AccD_Update needs a target and a status variable"));
        }
        let status = names.pop().unwrap();
        let target = names.remove(0);
        Ok(Stmt::Update { target, inputs: names, status })
    }

    fn iter(&mut self) -> Result<Stmt> {
        self.ident("AccD_Iter")?;
        self.expect(&TokenKind::LParen, "'('")?;
        let cond = match self.bump() {
            Some(TokenKind::Ident(s)) => IterCond::Status(s.clone()),
            Some(TokenKind::Number(n)) if n > 0.0 && n.fract() == 0.0 => {
                IterCond::MaxIters(n as usize)
            }
            other => return Err(self.err(&format!("expected exit condition, found {other:?}"))),
        };
        self.expect(&TokenKind::RParen, "')'")?;
        self.expect(&TokenKind::LBrace, "'{'")?;
        let mut body = Vec::new();
        while self.peek() != Some(&TokenKind::RBrace) {
            if self.at_end() {
                return Err(self.err("unterminated AccD_Iter block"));
            }
            body.push(self.stmt()?);
        }
        self.expect(&TokenKind::RBrace, "'}'")?;
        Ok(Stmt::Iter { cond, body })
    }

    fn assign(&mut self) -> Result<Stmt> {
        let name = self.ident("variable name")?;
        self.expect(&TokenKind::Eq, "'='")?;
        let value = match self.bump() {
            Some(TokenKind::Number(n)) => Value::Num(n),
            Some(TokenKind::Bool(b)) => Value::Bool(b),
            other => return Err(self.err(&format!("expected value, found {other:?}"))),
        };
        self.expect(&TokenKind::Semi, "';'")?;
        Ok(Stmt::Assign { name, value })
    }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    /// The paper's §III-F K-means program, verbatim structure.
    pub const KMEANS_DDSL: &str = r#"
        DVar K int 10;
        DVar D int 20;
        DVar psize int 1400;
        DVar csize int 200;
        DSet pSet float psize D;
        DSet cSet float csize D;
        DSet distMat float psize csize;
        DSet idMat int psize csize;
        DSet pkMat int psize K;
        DVar S int;
        AccD_Iter(S) {
            S = false;
            /* Compute the inter-dataset distances */
            AccD_Comp_Dist(pSet, cSet, distMat, idMat, D, "Unweighted L1", 0);
            /* Select the distances of interests */
            AccD_Dist_Select(distMat, idMat, K, "smallest", pkMat);
            /* Update the cluster center */
            AccD_Update(cSet, pSet, pkMat, S)
        }
    "#;

    #[test]
    fn parses_paper_kmeans_program() {
        let prog = parse(&lex(KMEANS_DDSL).unwrap()).unwrap();
        assert_eq!(prog.decls.len(), 10);
        assert_eq!(prog.body.len(), 1);
        let Stmt::Iter { cond, body } = &prog.body[0] else {
            panic!("expected AccD_Iter at top level");
        };
        assert_eq!(*cond, IterCond::Status("S".into()));
        assert_eq!(body.len(), 4);
        assert!(matches!(&body[1], Stmt::CompDist { metric, .. } if metric.norm == "L1"));
        assert!(
            matches!(&body[2], Stmt::DistSelect { scope, .. } if scope == "smallest")
        );
        assert!(matches!(&body[3], Stmt::Update { target, .. } if target == "cSet"));
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let err = parse(&lex("DVar x unknown;").unwrap()).unwrap_err();
        assert!(err.to_string().contains("line 1"), "{err}");
    }

    #[test]
    fn rejects_bad_scope() {
        let src = r#"
            DSet a float 10 2;
            AccD_Dist_Select(a, a, 3, "median", a);
        "#;
        assert!(parse(&lex(src).unwrap()).is_err());
    }

    #[test]
    fn iter_with_max_count() {
        let src = "AccD_Iter(25) { S = true; }";
        let prog = parse(&lex(src).unwrap()).unwrap();
        assert!(matches!(&prog.body[0], Stmt::Iter { cond: IterCond::MaxIters(25), .. }));
    }

    #[test]
    fn weighted_metric_with_weight_set() {
        let src = r#"
            AccD_Comp_Dist(a, b, dm, im, 8, "Weighted L2", wMat);
        "#;
        let prog = parse(&lex(src).unwrap()).unwrap();
        assert!(matches!(
            &prog.body[0],
            Stmt::CompDist { weight: Some(w), metric, .. }
                if w == "wMat" && metric.weighted
        ));
    }
}
