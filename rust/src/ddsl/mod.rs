//! DDSL — the Distance-related Domain-Specific Language (paper §III).
//!
//! A C-like language with five construct families:
//!
//! * **Definition**: `DVar name type [init];` and
//!   `DSet name type size dim;`
//! * **Operation**: `AccD_Comp_Dist(...)`, `AccD_Dist_Select(...)`,
//!   `AccD_Update(...)`
//! * **Control**: `AccD_Iter(cond|maxIter) { ... }` and scalar
//!   assignments like `S = false;`
//!
//! Compilation pipeline: [`lexer`] → [`parser`] → [`typecheck`] →
//! [`plan`].  The planner performs the paper's strategy selection: it
//! pattern-matches the (typed) program against the three GTI execution
//! templates — iterative/distinct-sets (K-means-like → Trace+Group),
//! one-shot Top-K (KNN-join-like → Two-landmark+Group), and
//! iterative/self-join (N-body-like → the full hybrid) — and emits an
//! [`plan::ExecutionPlan`] the engine can run.
//!
//! The K-means program from the paper's §III-F parses verbatim (modulo
//! whitespace); see `examples/ddsl/kmeans.dd`.

pub mod ast;
pub mod lexer;
pub mod parser;
pub mod plan;
pub mod typecheck;

pub use ast::Program;
pub use plan::{ExecutionPlan, GtiStrategy};

use crate::Result;

/// Full pipeline: source text → validated execution plan.
pub fn compile_program(src: &str) -> Result<ExecutionPlan> {
    let tokens = lexer::lex(src)?;
    let program = parser::parse(&tokens)?;
    let typed = typecheck::check(&program)?;
    plan::lower(&typed)
}
