//! DDSL planner: typed program → GTI execution plan.
//!
//! This is the compiler stage that embodies the paper's strategy table
//! (§VII intro): the program's *structure* decides which combination of
//! GTI bound computations applies:
//!
//! | pattern                                   | strategy              |
//! |-------------------------------------------|-----------------------|
//! | iterative, distinct sets, target updated  | Trace + Group         |
//! | one-shot Top-K                            | Two-landmark + Group  |
//! | iterative, self-join (src == trg updated) | Two-landmark + Trace + Group |
//!
//! The emitted [`ExecutionPlan`] names the engine entry point, the
//! metric, and the datasets to bind; `Engine`-side execution happens in
//! the CLI / examples where concrete data is attached.

use super::ast::{IterCond, Metric, SizeExpr, Stmt};
use super::typecheck::TypedProgram;
use crate::{Error, Result};

/// Which GTI bound computations the plan enables (paper §IV-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GtiStrategy {
    pub two_landmark: bool,
    pub trace_based: bool,
    pub group_level: bool,
}

impl std::fmt::Display for GtiStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut parts = Vec::new();
        if self.two_landmark {
            parts.push("Two-landmark");
        }
        if self.trace_based {
            parts.push("Trace-based");
        }
        if self.group_level {
            parts.push("Group-level");
        }
        write!(f, "{}", parts.join(" + "))
    }
}

/// The algorithm family the planner recognized.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanKind {
    /// Iterative clustering: assign + update target set.
    KmeansLike {
        points: String,
        centers: String,
        k: usize,
        max_iters: usize,
    },
    /// One-shot Top-K join.
    KnnJoinLike { src: String, trg: String, k: usize },
    /// One-shot radius query: all target points within `threshold`.
    RangeJoinLike { src: String, trg: String, threshold: f64 },
    /// Iterative self-join with radius selection.
    NbodyLike { particles: String, radius_expr: usize, max_iters: usize },
}

/// A complete, runnable plan.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    pub kind: PlanKind,
    pub strategy: GtiStrategy,
    pub metric: Metric,
    /// Set shapes the runner must bind, `(name, size, dim)`.
    pub bindings: Vec<(String, usize, usize)>,
}

/// Default iteration cap for status-variable loops (the paper's
/// convergence-driven `AccD_Iter(S)` form).
const DEFAULT_MAX_ITERS: usize = 50;

pub fn lower(tp: &TypedProgram) -> Result<ExecutionPlan> {
    // Locate the (single) CompDist, the Select, and whether they sit in
    // an Iter with an Update.
    let (iter, body): (Option<&IterCond>, &[Stmt]) = match tp.body.as_slice() {
        [Stmt::Iter { cond, body }] => (Some(cond), body.as_slice()),
        other => (None, other),
    };

    let comp = body.iter().find_map(|s| match s {
        Stmt::CompDist { src, trg, metric, .. } => Some((src, trg, metric)),
        _ => None,
    });
    let select = body.iter().find_map(|s| match s {
        Stmt::DistSelect { range, scope, .. } => Some((range, scope)),
        _ => None,
    });
    let update = body.iter().find_map(|s| match s {
        Stmt::Update { target, .. } => Some(target),
        _ => None,
    });

    let (src, trg, metric) = comp.ok_or_else(|| {
        Error::Ddsl("program contains no AccD_Comp_Dist — nothing to accelerate".into())
    })?;
    let (range, scope) = select.ok_or_else(|| {
        Error::Ddsl("program contains no AccD_Dist_Select — result undefined".into())
    })?;

    let src_info = tp.set(src)?;
    let trg_info = tp.set(trg)?;
    // Weighted metrics survive parsing and typecheck (the weight
    // matrix is shape-checked there) but no execution path applies
    // weights yet — reject here instead of silently computing
    // unweighted distances.
    if metric.weighted {
        return Err(Error::Ddsl(format!(
            "weighted metric \"{}\" is not yet implemented — the engine would \
             silently compute unweighted distances; use an unweighted metric",
            metric.norm
        )));
    }
    // The selection range is kept as f64 here: "within" thresholds are
    // legitimately fractional, while Top-K counts and N-body radii
    // must be exact non-negative integers (validated per branch below,
    // naming the variable).
    let (range_val, range_name): (f64, Option<&str>) = match range {
        SizeExpr::Lit(n) => (*n as f64, None),
        SizeExpr::Var(name) => match tp.vars.get(name).and_then(|v| v.init.clone()) {
            Some(super::ast::Value::Num(n)) => (n, Some(name.as_str())),
            _ => {
                return Err(Error::Ddsl(format!(
                    "selection range {name:?} has no numeric value"
                )))
            }
        },
    };
    // Exact non-negative integer selection count/radius, or an error
    // naming the offending variable (fractional and negative values
    // used to be silently truncated by `as usize`).
    let integer_range = |what: &str| -> Result<usize> {
        if range_val < 0.0 || range_val.fract() != 0.0 || !range_val.is_finite() {
            let source = range_name
                .map(|n| format!("variable {n:?}"))
                .unwrap_or_else(|| "literal".to_string());
            return Err(Error::Ddsl(format!(
                "{what} must be a non-negative integer, but {source} is {range_val}"
            )));
        }
        Ok(range_val as usize)
    };
    let max_iters = match iter {
        Some(IterCond::MaxIters(n)) => *n,
        Some(IterCond::Status(_)) => DEFAULT_MAX_ITERS,
        None => 1,
    };

    let bindings = vec![
        (src_info.name.clone(), src_info.size, src_info.dim),
        (trg_info.name.clone(), trg_info.size, trg_info.dim),
    ];

    // Strategy selection (the paper's table).  Every branch validates
    // the selection *scope* — a program whose scope does not fit its
    // structure is an error, never a silent re-interpretation.
    let plan = if iter.is_some() && src == trg {
        // Self-join, iterative: N-body family — a radius interaction,
        // so the selection must be "within".
        if scope != "within" {
            return Err(Error::Ddsl(format!(
                "iterative self-join requires \"within\" selection (interaction \
                 radius), got {scope:?}"
            )));
        }
        ExecutionPlan {
            kind: PlanKind::NbodyLike {
                particles: src.clone(),
                radius_expr: integer_range("N-body interaction radius")?,
                max_iters,
            },
            strategy: GtiStrategy { two_landmark: true, trace_based: true, group_level: true },
            metric: metric.clone(),
            bindings,
        }
    } else if iter.is_some() && update.map(|u| u == trg).unwrap_or(false) {
        // Iterative with target update: K-means family.
        if scope != "smallest" {
            return Err(Error::Ddsl(format!(
                "clustering requires \"smallest\" selection, got {scope:?}"
            )));
        }
        ExecutionPlan {
            kind: PlanKind::KmeansLike {
                points: src.clone(),
                centers: trg.clone(),
                k: trg_info.size,
                max_iters,
            },
            strategy: GtiStrategy { two_landmark: false, trace_based: true, group_level: true },
            metric: metric.clone(),
            bindings,
        }
    } else if iter.is_none() {
        // One-shot join: dispatch on the selection scope.  "smallest"
        // is Top-K (KNN family); "within" is a radius query (range
        // join) — it used to fall into the Top-K branch and silently
        // lower to KnnJoinLike { k: threshold }.
        match scope.as_str() {
            "smallest" => {
                let k = integer_range("Top-K selection count")?;
                if k == 0 || k > trg_info.size {
                    return Err(Error::Ddsl(format!(
                        "Top-K range {k} out of bounds for target size {}",
                        trg_info.size
                    )));
                }
                ExecutionPlan {
                    kind: PlanKind::KnnJoinLike { src: src.clone(), trg: trg.clone(), k },
                    strategy: GtiStrategy {
                        two_landmark: true,
                        trace_based: false,
                        group_level: true,
                    },
                    metric: metric.clone(),
                    bindings,
                }
            }
            "within" => {
                if !(range_val.is_finite() && range_val > 0.0) {
                    let source = range_name
                        .map(|n| format!("variable {n:?}"))
                        .unwrap_or_else(|| "literal".to_string());
                    return Err(Error::Ddsl(format!(
                        "range-join threshold must be finite and positive, but \
                         {source} is {range_val}"
                    )));
                }
                ExecutionPlan {
                    kind: PlanKind::RangeJoinLike {
                        src: src.clone(),
                        trg: trg.clone(),
                        threshold: range_val,
                    },
                    strategy: GtiStrategy {
                        two_landmark: true,
                        trace_based: false,
                        group_level: true,
                    },
                    metric: metric.clone(),
                    bindings,
                }
            }
            other => {
                return Err(Error::Ddsl(format!(
                    "one-shot join supports \"smallest\" (Top-K) or \"within\" \
                     (range join) selection; {other:?} is not supported"
                )))
            }
        }
    } else {
        return Err(Error::Ddsl(
            "unrecognized program pattern: iterative without target update".into(),
        ));
    };
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::super::compile_program;
    use super::*;

    const KMEANS: &str = r#"
        DVar K int 10;
        DVar D int 20;
        DVar psize int 1400;
        DVar csize int 200;
        DSet pSet float psize D;
        DSet cSet float csize D;
        DSet distMat float psize csize;
        DSet idMat int psize csize;
        DSet pkMat int psize K;
        DVar S int;
        AccD_Iter(S) {
            S = false;
            AccD_Comp_Dist(pSet, cSet, distMat, idMat, D, "Unweighted L1", 0);
            AccD_Dist_Select(distMat, idMat, K, "smallest", pkMat);
            AccD_Update(cSet, pSet, pkMat, S)
        }
    "#;

    #[test]
    fn kmeans_program_selects_trace_plus_group() {
        let plan = compile_program(KMEANS).unwrap();
        assert!(matches!(
            plan.kind,
            PlanKind::KmeansLike { k: 200, .. }
        ));
        assert_eq!(
            plan.strategy,
            GtiStrategy { two_landmark: false, trace_based: true, group_level: true }
        );
        assert_eq!(plan.metric.norm, "L1");
        assert_eq!(plan.strategy.to_string(), "Trace-based + Group-level");
    }

    #[test]
    fn knn_program_selects_two_landmark_plus_group() {
        let src = r#"
            DVar K int 5;
            DSet q float 100 4;
            DSet t float 300 4;
            DSet dm float 100 300;
            DSet im int 100 300;
            DSet outM int 100 K;
            AccD_Comp_Dist(q, t, dm, im, 4, "L2", 0);
            AccD_Dist_Select(dm, im, K, "smallest", outM);
        "#;
        let plan = compile_program(src).unwrap();
        assert!(matches!(plan.kind, PlanKind::KnnJoinLike { k: 5, .. }));
        assert_eq!(
            plan.strategy,
            GtiStrategy { two_landmark: true, trace_based: false, group_level: true }
        );
    }

    #[test]
    fn nbody_program_selects_full_hybrid() {
        let src = r#"
            DVar R int 2;
            DVar S int;
            DSet p float 500 3;
            DSet dm float 500 500;
            DSet im int 500 500;
            DSet nb int 500 R;
            AccD_Iter(30) {
                AccD_Comp_Dist(p, p, dm, im, 3, "L2", 0);
                AccD_Dist_Select(dm, im, R, "within", nb);
                AccD_Update(p, nb, S)
            }
        "#;
        let plan = compile_program(src).unwrap();
        assert!(matches!(plan.kind, PlanKind::NbodyLike { max_iters: 30, .. }));
        assert_eq!(
            plan.strategy,
            GtiStrategy { two_landmark: true, trace_based: true, group_level: true }
        );
    }

    #[test]
    fn program_without_comp_dist_is_rejected() {
        let err = compile_program("DVar x int 1; x = 2;").unwrap_err();
        assert!(err.to_string().contains("AccD_Comp_Dist"), "{err}");
    }

    #[test]
    fn topk_out_of_range_rejected() {
        let src = r#"
            DSet q float 10 2;
            DSet t float 5 2;
            DSet dm float 10 5;
            DSet im int 10 5;
            DSet o int 10 9;
            AccD_Comp_Dist(q, t, dm, im, 2, "L2", 0);
            AccD_Dist_Select(dm, im, 9, "smallest", o);
        "#;
        assert!(compile_program(src).is_err());
    }

    /// The exact program shape that used to miscompile: a one-shot
    /// `"within"` selection fell into the Top-K branch (scope was never
    /// checked there) and lowered to `KnnJoinLike { k: T }` — the T
    /// nearest neighbors instead of all neighbors within distance T.
    const ONESHOT_WITHIN: &str = r#"
        DVar T float 0.5;
        DSet q float 100 4;
        DSet t float 300 4;
        DSet dm float 100 300;
        DSet im int 100 300;
        DSet outM int 100 300;
        AccD_Comp_Dist(q, t, dm, im, 4, "L2", 0);
        AccD_Dist_Select(dm, im, T, "within", outM);
    "#;

    #[test]
    fn oneshot_within_lowers_to_range_join_not_topk() {
        let plan = compile_program(ONESHOT_WITHIN).unwrap();
        assert!(
            !matches!(plan.kind, PlanKind::KnnJoinLike { .. }),
            "one-shot \"within\" must never silently lower to Top-K"
        );
        match &plan.kind {
            PlanKind::RangeJoinLike { src, trg, threshold } => {
                assert_eq!(src, "q");
                assert_eq!(trg, "t");
                assert_eq!(*threshold, 0.5);
            }
            other => panic!("expected RangeJoinLike, got {other:?}"),
        }
        assert_eq!(
            plan.strategy,
            GtiStrategy { two_landmark: true, trace_based: false, group_level: true }
        );
    }

    #[test]
    fn oneshot_largest_is_rejected_not_reinterpreted() {
        let src = r#"
            DSet q float 10 2;
            DSet t float 5 2;
            DSet dm float 10 5;
            DSet im int 10 5;
            DSet o int 10 3;
            AccD_Comp_Dist(q, t, dm, im, 2, "L2", 0);
            AccD_Dist_Select(dm, im, 3, "largest", o);
        "#;
        let err = compile_program(src).unwrap_err();
        assert!(err.to_string().contains("largest"), "{err}");
    }

    #[test]
    fn nbody_branch_requires_within_scope() {
        let src = r#"
            DVar R int 2;
            DVar S int;
            DSet p float 500 3;
            DSet dm float 500 500;
            DSet im int 500 500;
            DSet nb int 500 R;
            AccD_Iter(30) {
                AccD_Comp_Dist(p, p, dm, im, 3, "L2", 0);
                AccD_Dist_Select(dm, im, R, "smallest", nb);
                AccD_Update(p, nb, S)
            }
        "#;
        let err = compile_program(src).unwrap_err();
        assert!(err.to_string().contains("within"), "{err}");
    }

    #[test]
    fn weighted_metric_rejected_at_plan_time() {
        // Weighted metrics parse and typecheck (the weight matrix is
        // shape-checked) but no execution path applies weights; the
        // planner must say so instead of computing unweighted
        // distances silently.
        let src = r#"
            DSet a float 50 6;
            DSet b float 90 6;
            DSet w float 1 6;
            DSet dm float 50 90;
            DSet im int 50 90;
            DSet sel int 50 10;
            AccD_Comp_Dist(a, b, dm, im, 6, "Weighted L1", w);
            AccD_Dist_Select(dm, im, 10, "smallest", sel);
        "#;
        let err = compile_program(src).unwrap_err();
        assert!(err.to_string().contains("not yet implemented"), "{err}");
    }

    #[test]
    fn fractional_topk_range_rejected_naming_the_variable() {
        // `DVar K int 2.9` used to silently truncate to K=2.
        let src = r#"
            DVar K int 2.9;
            DSet q float 10 2;
            DSet t float 5 2;
            DSet dm float 10 5;
            DSet im int 10 5;
            DSet o int 10 2;
            AccD_Comp_Dist(q, t, dm, im, 2, "L2", 0);
            AccD_Dist_Select(dm, im, K, "smallest", o);
        "#;
        let err = compile_program(src).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("\"K\"") && msg.contains("2.9"), "{msg}");
    }

    #[test]
    fn negative_selection_range_rejected_naming_the_variable() {
        // Negative values used to saturate to 0 via `as usize`.
        let src = r#"
            DVar K int -3;
            DSet q float 10 2;
            DSet t float 5 2;
            DSet dm float 10 5;
            DSet im int 10 5;
            DSet o int 10 5;
            AccD_Comp_Dist(q, t, dm, im, 2, "L2", 0);
            AccD_Dist_Select(dm, im, K, "smallest", o);
        "#;
        let err = compile_program(src).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("\"K\"") && msg.contains("-3"), "{msg}");
    }

    #[test]
    fn nonpositive_within_threshold_rejected() {
        let src = r#"
            DVar T float 0.0;
            DSet q float 10 2;
            DSet t float 5 2;
            DSet dm float 10 5;
            DSet im int 10 5;
            DSet o int 10 5;
            AccD_Comp_Dist(q, t, dm, im, 2, "L2", 0);
            AccD_Dist_Select(dm, im, T, "within", o);
        "#;
        let err = compile_program(src).unwrap_err();
        assert!(err.to_string().contains("finite and positive"), "{err}");
    }
}
