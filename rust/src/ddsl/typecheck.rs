//! DDSL semantic analysis: symbol resolution + shape/type checking.
//!
//! Produces a [`TypedProgram`] in which every `SizeExpr` is resolved to
//! a concrete value and every referenced name is verified to exist with
//! the right kind (scalar vs set) and compatible shape.

use std::collections::HashMap;

use super::ast::*;
use crate::{Error, Result};

/// A resolved DSet: concrete rows/cols.
#[derive(Debug, Clone, PartialEq)]
pub struct SetInfo {
    pub name: String,
    pub ty: DType,
    pub size: usize,
    pub dim: usize,
}

/// A resolved scalar variable.
#[derive(Debug, Clone, PartialEq)]
pub struct VarInfo {
    pub name: String,
    pub ty: DType,
    pub init: Option<Value>,
}

/// The validated program: symbol tables + the original statement tree.
#[derive(Debug, Clone)]
pub struct TypedProgram {
    pub vars: HashMap<String, VarInfo>,
    pub sets: HashMap<String, SetInfo>,
    pub body: Vec<Stmt>,
}

impl TypedProgram {
    pub fn set(&self, name: &str) -> Result<&SetInfo> {
        self.sets
            .get(name)
            .ok_or_else(|| Error::Ddsl(format!("undeclared DSet {name:?}")))
    }
}

/// Resolve a size expression against the scalar table.
fn resolve(vars: &HashMap<String, VarInfo>, e: &SizeExpr) -> Result<usize> {
    match e {
        SizeExpr::Lit(n) => Ok(*n),
        SizeExpr::Var(name) => {
            let v = vars
                .get(name)
                .ok_or_else(|| Error::Ddsl(format!("undeclared size variable {name:?}")))?;
            match v.init {
                Some(Value::Num(n)) if n >= 0.0 && n.fract() == 0.0 => Ok(n as usize),
                _ => Err(Error::Ddsl(format!(
                    "size variable {name:?} has no integer initializer"
                ))),
            }
        }
    }
}

pub fn check(program: &Program) -> Result<TypedProgram> {
    let mut vars: HashMap<String, VarInfo> = HashMap::new();
    let mut sets: HashMap<String, SetInfo> = HashMap::new();
    for d in &program.decls {
        match d {
            Decl::Var { name, ty, init } => {
                if vars.contains_key(name) || sets.contains_key(name) {
                    return Err(Error::Ddsl(format!("duplicate declaration {name:?}")));
                }
                vars.insert(
                    name.clone(),
                    VarInfo { name: name.clone(), ty: *ty, init: init.clone() },
                );
            }
            Decl::Set { name, ty, size, dim } => {
                if vars.contains_key(name) || sets.contains_key(name) {
                    return Err(Error::Ddsl(format!("duplicate declaration {name:?}")));
                }
                let size = resolve(&vars, size)?;
                let dim = resolve(&vars, dim)?;
                if size == 0 || dim == 0 {
                    return Err(Error::Ddsl(format!("DSet {name:?} has zero extent")));
                }
                sets.insert(
                    name.clone(),
                    SetInfo { name: name.clone(), ty: *ty, size, dim },
                );
            }
        }
    }

    // Walk statements, validating references.
    fn walk(
        stmts: &[Stmt],
        vars: &HashMap<String, VarInfo>,
        sets: &HashMap<String, SetInfo>,
    ) -> Result<()> {
        for s in stmts {
            match s {
                Stmt::CompDist { src, trg, dist_mat, id_mat, dim, metric, weight } => {
                    let si = sets
                        .get(src)
                        .ok_or_else(|| Error::Ddsl(format!("undeclared source set {src:?}")))?;
                    let ti = sets
                        .get(trg)
                        .ok_or_else(|| Error::Ddsl(format!("undeclared target set {trg:?}")))?;
                    if si.dim != ti.dim {
                        return Err(Error::Ddsl(format!(
                            "dimension mismatch: {src} is d={}, {trg} is d={}",
                            si.dim, ti.dim
                        )));
                    }
                    let d = resolve(vars, dim)?;
                    if d != si.dim {
                        return Err(Error::Ddsl(format!(
                            "AccD_Comp_Dist dim {d} != set dimension {}",
                            si.dim
                        )));
                    }
                    let dm = sets.get(dist_mat).ok_or_else(|| {
                        Error::Ddsl(format!("undeclared distance matrix {dist_mat:?}"))
                    })?;
                    if dm.size != si.size || dm.dim != ti.size {
                        return Err(Error::Ddsl(format!(
                            "distance matrix {dist_mat} is {}x{}, expected {}x{}",
                            dm.size, dm.dim, si.size, ti.size
                        )));
                    }
                    if !sets.contains_key(id_mat) {
                        return Err(Error::Ddsl(format!("undeclared id matrix {id_mat:?}")));
                    }
                    if metric.weighted {
                        let w = weight.as_ref().ok_or_else(|| {
                            Error::Ddsl("weighted metric requires a weight matrix".into())
                        })?;
                        let wi = sets.get(w).ok_or_else(|| {
                            Error::Ddsl(format!("undeclared weight matrix {w:?}"))
                        })?;
                        if wi.dim != si.dim && wi.size != si.dim {
                            return Err(Error::Ddsl(format!(
                                "weight matrix {w} has shape {}x{}, expected 1x{}",
                                wi.size, wi.dim, si.dim
                            )));
                        }
                    }
                }
                Stmt::DistSelect { dist_mat, id_mat, range, out_mat, .. } => {
                    for m in [dist_mat, id_mat, out_mat] {
                        if !sets.contains_key(m) {
                            return Err(Error::Ddsl(format!("undeclared matrix {m:?}")));
                        }
                    }
                    // The selection range may be a Top-K count OR a
                    // fractional "within" threshold, so only name
                    // resolution and numeric-ness are checked here;
                    // the planner validates integer-ness per scope.
                    if let SizeExpr::Var(name) = range {
                        let v = vars.get(name).ok_or_else(|| {
                            Error::Ddsl(format!("undeclared selection range {name:?}"))
                        })?;
                        if !matches!(v.init, Some(Value::Num(_))) {
                            return Err(Error::Ddsl(format!(
                                "selection range {name:?} has no numeric initializer"
                            )));
                        }
                    }
                }
                Stmt::Update { target, inputs, status } => {
                    if !sets.contains_key(target) {
                        return Err(Error::Ddsl(format!("undeclared update target {target:?}")));
                    }
                    for i in inputs {
                        if !sets.contains_key(i) && !vars.contains_key(i) {
                            return Err(Error::Ddsl(format!("undeclared update input {i:?}")));
                        }
                    }
                    if !vars.contains_key(status) {
                        return Err(Error::Ddsl(format!(
                            "undeclared status variable {status:?}"
                        )));
                    }
                }
                Stmt::Iter { cond, body } => {
                    if let IterCond::Status(name) = cond {
                        if !vars.contains_key(name) {
                            return Err(Error::Ddsl(format!(
                                "undeclared iteration status variable {name:?}"
                            )));
                        }
                    }
                    walk(body, vars, sets)?;
                }
                Stmt::Assign { name, .. } => {
                    if !vars.contains_key(name) {
                        return Err(Error::Ddsl(format!("assignment to undeclared {name:?}")));
                    }
                }
            }
        }
        Ok(())
    }
    walk(&program.body, &vars, &sets)?;

    Ok(TypedProgram { vars, sets, body: program.body.clone() })
}

#[cfg(test)]
mod tests {
    use super::super::{lexer::lex, parser::parse};
    use super::*;

    fn compile(src: &str) -> Result<TypedProgram> {
        check(&parse(&lex(src).unwrap())?)
    }

    #[test]
    fn resolves_sizes_through_dvars() {
        let t = compile(
            "DVar n int 100; DVar d int 8; DSet a float n d;",
        )
        .unwrap();
        let a = t.set("a").unwrap();
        assert_eq!((a.size, a.dim), (100, 8));
    }

    #[test]
    fn rejects_undeclared_references() {
        assert!(compile(
            r#"DSet a float 10 2; DSet dm float 10 10; DSet im int 10 10;
               AccD_Comp_Dist(a, ghost, dm, im, 2, "L2", 0);"#
        )
        .is_err());
    }

    #[test]
    fn rejects_dim_mismatch() {
        assert!(compile(
            r#"DSet a float 10 2; DSet b float 5 3;
               DSet dm float 10 5; DSet im int 10 5;
               AccD_Comp_Dist(a, b, dm, im, 2, "L2", 0);"#
        )
        .is_err());
    }

    #[test]
    fn rejects_wrong_distance_matrix_shape() {
        assert!(compile(
            r#"DSet a float 10 2; DSet b float 5 2;
               DSet dm float 10 7; DSet im int 10 5;
               AccD_Comp_Dist(a, b, dm, im, 2, "L2", 0);"#
        )
        .is_err());
    }

    #[test]
    fn rejects_duplicate_and_zero_extent() {
        assert!(compile("DVar x int 1; DVar x int 2;").is_err());
        assert!(compile("DSet a float 0 2;").is_err());
    }

    #[test]
    fn weighted_metric_requires_weights() {
        assert!(compile(
            r#"DSet a float 4 2; DSet b float 4 2;
               DSet dm float 4 4; DSet im int 4 4;
               AccD_Comp_Dist(a, b, dm, im, 2, "Weighted L2", 0);"#
        )
        .is_err());
    }
}
