//! DDSL lexer: source text → token stream with positions.

use crate::{Error, Result};

/// One token with its source line (for diagnostics).
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    pub kind: TokenKind,
    pub line: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TokenKind {
    /// Identifiers and keywords (`DVar`, `AccD_Iter`, names, types).
    Ident(String),
    /// Integer or float literal.
    Number(f64),
    /// Double-quoted string (metric names like "Unweighted L1").
    Str(String),
    /// `true` / `false` keywords lex as Bool.
    Bool(bool),
    LParen,
    RParen,
    LBrace,
    RBrace,
    Comma,
    Semi,
    Eq,
    /// `!` (used in exit conditions like `!S`).
    Bang,
}

/// Lex a DDSL source file.  `/* ... */` and `// ...` comments are
/// skipped; unknown characters are hard errors with line info.
pub fn lex(src: &str) -> Result<Vec<Token>> {
    let mut out = Vec::new();
    let bytes = src.as_bytes();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < bytes.len() {
        let c = bytes[i] as char;
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            ' ' | '\t' | '\r' => i += 1,
            '/' if bytes.get(i + 1) == Some(&b'*') => {
                // block comment
                let start_line = line;
                i += 2;
                loop {
                    if i + 1 >= bytes.len() {
                        return Err(Error::Ddsl(format!(
                            "unterminated comment starting line {start_line}"
                        )));
                    }
                    if bytes[i] == b'*' && bytes[i + 1] == b'/' {
                        i += 2;
                        break;
                    }
                    if bytes[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            '/' if bytes.get(i + 1) == Some(&b'/') => {
                while i < bytes.len() && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            '(' => {
                out.push(Token { kind: TokenKind::LParen, line });
                i += 1;
            }
            ')' => {
                out.push(Token { kind: TokenKind::RParen, line });
                i += 1;
            }
            '{' => {
                out.push(Token { kind: TokenKind::LBrace, line });
                i += 1;
            }
            '}' => {
                out.push(Token { kind: TokenKind::RBrace, line });
                i += 1;
            }
            ',' => {
                out.push(Token { kind: TokenKind::Comma, line });
                i += 1;
            }
            ';' => {
                out.push(Token { kind: TokenKind::Semi, line });
                i += 1;
            }
            '=' => {
                out.push(Token { kind: TokenKind::Eq, line });
                i += 1;
            }
            '!' => {
                out.push(Token { kind: TokenKind::Bang, line });
                i += 1;
            }
            '"' => {
                let start = i + 1;
                let mut j = start;
                while j < bytes.len() && bytes[j] != b'"' {
                    if bytes[j] == b'\n' {
                        return Err(Error::Ddsl(format!("unterminated string on line {line}")));
                    }
                    j += 1;
                }
                if j >= bytes.len() {
                    return Err(Error::Ddsl(format!("unterminated string on line {line}")));
                }
                out.push(Token {
                    kind: TokenKind::Str(src[start..j].to_string()),
                    line,
                });
                i = j + 1;
            }
            c if c.is_ascii_digit()
                || (c == '-' && bytes.get(i + 1).is_some_and(|b| b.is_ascii_digit())) =>
            {
                let start = i;
                i += 1;
                while i < bytes.len()
                    && (bytes[i].is_ascii_digit() || bytes[i] == b'.' || bytes[i] == b'e')
                {
                    i += 1;
                }
                let text = &src[start..i];
                let n = text.parse::<f64>().map_err(|_| {
                    Error::Ddsl(format!("bad number {text:?} on line {line}"))
                })?;
                out.push(Token { kind: TokenKind::Number(n), line });
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                while i < bytes.len()
                    && ((bytes[i] as char).is_ascii_alphanumeric() || bytes[i] == b'_')
                {
                    i += 1;
                }
                let word = &src[start..i];
                let kind = match word {
                    "true" => TokenKind::Bool(true),
                    "false" => TokenKind::Bool(false),
                    _ => TokenKind::Ident(word.to_string()),
                };
                out.push(Token { kind, line });
            }
            other => {
                return Err(Error::Ddsl(format!(
                    "unexpected character {other:?} on line {line}"
                )))
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_paper_snippet() {
        let toks = lex(r#"
            /* Define a single variable */
            DVar K int 10;
            AccD_Comp_Dist(pSet, cSet, distMat, idMat, D, "Unweighted L1", 0);
        "#)
        .unwrap();
        assert!(matches!(&toks[0].kind, TokenKind::Ident(s) if s == "DVar"));
        assert!(matches!(toks[2].kind, TokenKind::Ident(ref s) if s == "int"));
        assert!(matches!(toks[3].kind, TokenKind::Number(n) if n == 10.0));
        assert!(toks.iter().any(|t| matches!(&t.kind, TokenKind::Str(s) if s == "Unweighted L1")));
    }

    #[test]
    fn tracks_line_numbers_through_comments() {
        let toks = lex("// comment\n/* multi\nline */\nDVar x int;\n").unwrap();
        assert_eq!(toks[0].line, 4);
    }

    #[test]
    fn booleans_and_bang() {
        let toks = lex("S = false; !S").unwrap();
        assert!(matches!(toks[2].kind, TokenKind::Bool(false)));
        assert!(matches!(toks[4].kind, TokenKind::Bang));
    }

    #[test]
    fn rejects_unterminated_string_and_comment() {
        assert!(lex("\"abc").is_err());
        assert!(lex("/* abc").is_err());
        assert!(lex("$").is_err());
    }

    #[test]
    fn negative_numbers() {
        let toks = lex("DVar t float -1.5;").unwrap();
        assert!(matches!(toks[3].kind, TokenKind::Number(n) if n == -1.5));
    }
}
