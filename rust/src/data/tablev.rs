//! The paper's Table V evaluation datasets, as generator specs.
//!
//! Sizes, dimensionalities and cluster counts match Table V exactly;
//! point values are synthetic (clustered Gaussian mixtures / Plummer
//! spheres) because the original UCI files are not distributed with the
//! repo.  `DatasetSpec::generate` is deterministic in the spec's seed.

use super::{synthetic, Dataset};

/// Which benchmark family a spec belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Kmeans,
    KnnJoin,
    Nbody,
}

/// One row of Table V.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub family: Family,
    /// Paper's dataset name (provenance label only).
    pub name: &'static str,
    pub size: usize,
    pub dim: usize,
    /// K-means: #Cluster column; KNN-join: fixed K=1000 neighbors per the
    /// paper's setup; N-body: unused (radius search).
    pub k: usize,
    pub seed: u64,
}

impl DatasetSpec {
    /// Generate the synthetic stand-in point set.
    pub fn generate(&self) -> Dataset {
        let mut ds = match self.family {
            // ~sqrt(n) latent modes gives realistic multi-scale cluster
            // structure (clusters of clusters), matching how UCI data
            // behaves under TI filtering far better than pure uniform.
            Family::Kmeans | Family::KnnJoin => {
                let modes = (self.size as f64).sqrt() as usize / 2;
                synthetic::clustered(self.size, self.dim, modes.max(8), 0.03, self.seed)
            }
            Family::Nbody => synthetic::plummer(self.size, 1.0, self.seed),
        };
        ds.name = format!("{}(n={},d={})", self.name, self.size, self.dim);
        ds
    }

    /// A proportionally scaled-down copy (for quick CI runs).
    pub fn scaled(&self, factor: f64) -> DatasetSpec {
        let mut s = self.clone();
        s.size = ((self.size as f64 * factor) as usize).max(256);
        s.k = ((self.k as f64 * factor.sqrt()) as usize).clamp(4, s.size / 4);
        s
    }
}

/// Table V, K-means block (name, size, dimension, #cluster).
pub fn kmeans_datasets() -> Vec<DatasetSpec> {
    [
        ("Poker Hand", 25_010, 11, 158),
        ("Smartwatch Sens", 58_371, 12, 242),
        ("Healthy Older People", 75_128, 9, 274),
        ("KDD Cup 2004", 285_409, 74, 534),
        ("Kegg Net Undirected", 65_554, 28, 256),
        ("Ipums", 70_187, 60, 265),
    ]
    .iter()
    .enumerate()
    .map(|(i, &(name, size, dim, k))| DatasetSpec {
        family: Family::Kmeans,
        name,
        size,
        dim,
        k,
        seed: 0x5EED_0000 + i as u64,
    })
    .collect()
}

/// Table V, KNN-join block (K = 1000 nearest neighbors in the paper).
pub fn knn_datasets() -> Vec<DatasetSpec> {
    [
        ("Harddrive1", 68_411, 64),
        ("Kegg Net Directed", 53_413, 24),
        ("3D Spatial Network", 434_874, 3),
        ("KDD Cup 1998", 95_413, 56),
        ("Skin NonSkin", 245_057, 4),
        ("Protein", 26_611, 11),
    ]
    .iter()
    .enumerate()
    .map(|(i, &(name, size, dim))| DatasetSpec {
        family: Family::KnnJoin,
        name,
        size,
        dim,
        k: 1000,
        seed: 0x5EED_1000 + i as u64,
    })
    .collect()
}

/// Table V, N-body block (particle counts P-1..P-6).
pub fn nbody_datasets() -> Vec<DatasetSpec> {
    [16_384usize, 32_768, 59_049, 78_125, 177_147, 262_144]
        .iter()
        .enumerate()
        .map(|(i, &n)| DatasetSpec {
            family: Family::Nbody,
            name: ["P-1", "P-2", "P-3", "P-4", "P-5", "P-6"][i],
            size: n,
            dim: 3,
            k: 0,
            seed: 0x5EED_2000 + i as u64,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tablev_counts_match_paper() {
        assert_eq!(kmeans_datasets().len(), 6);
        assert_eq!(knn_datasets().len(), 6);
        assert_eq!(nbody_datasets().len(), 6);
    }

    #[test]
    fn kdd2004_spec_matches_paper_row() {
        let specs = kmeans_datasets();
        let kdd = specs.iter().find(|s| s.name == "KDD Cup 2004").unwrap();
        assert_eq!((kdd.size, kdd.dim, kdd.k), (285_409, 74, 534));
    }

    #[test]
    fn generate_respects_spec_shape() {
        let spec = knn_datasets()[5].scaled(0.05); // Protein, small
        let ds = spec.generate();
        assert_eq!(ds.n(), spec.size);
        assert_eq!(ds.d(), spec.dim);
    }

    #[test]
    fn scaled_keeps_minimums() {
        let s = kmeans_datasets()[0].scaled(1e-6);
        assert!(s.size >= 256);
        assert!(s.k >= 4);
    }

    #[test]
    fn nbody_dims_are_3d() {
        assert!(nbody_datasets().iter().all(|s| s.dim == 3));
    }
}
