//! Datasets and dense matrices.
//!
//! The paper evaluates on UCI datasets (Table V) that are not shipped
//! with this repository; `synthetic` generates statistically comparable
//! stand-ins (same size/dimension, mixture-of-Gaussians structure so TI
//! filtering has real pruning opportunities — see DESIGN.md
//! §Substitutions), and `loader` reads CSV for users who have the real
//! files.

pub mod loader;
pub mod synthetic;
pub mod tablev;

pub use tablev::{kmeans_datasets, knn_datasets, nbody_datasets, DatasetSpec};

use crate::{Error, Result};

/// Dense row-major f32 matrix — the point-set container used everywhere.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { data: vec![0.0; rows * cols], rows, cols }
    }

    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "matrix data length {} != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Self { data, rows, cols })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Gather rows by index into a new matrix (layout optimizer core op).
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (o, &i) in idx.iter().enumerate() {
            out.row_mut(o).copy_from_slice(self.row(i));
        }
        out
    }

    /// Copy rows into a zero-padded buffer of `rows_padded x cols_padded`
    /// (feature axis zero-padding is distance-neutral for L2^2/L1).
    pub fn padded(&self, rows_padded: usize, cols_padded: usize) -> Result<Vec<f32>> {
        if rows_padded < self.rows || cols_padded < self.cols {
            return Err(Error::Shape(format!(
                "padded shape {rows_padded}x{cols_padded} smaller than {}x{}",
                self.rows, self.cols
            )));
        }
        let mut out = vec![0.0f32; rows_padded * cols_padded];
        for i in 0..self.rows {
            out[i * cols_padded..i * cols_padded + self.cols].copy_from_slice(self.row(i));
        }
        Ok(out)
    }

    /// Squared L2 distance between row `i` and `other`'s row `j`.
    #[inline]
    pub fn dist2(&self, i: usize, other: &Matrix, j: usize) -> f32 {
        let (a, b) = (self.row(i), other.row(j));
        let mut s = 0.0f32;
        for k in 0..self.cols {
            let d = a[k] - b[k];
            s += d * d;
        }
        s
    }
}

/// A named point set plus provenance, the unit the engine operates on.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub points: Matrix,
    /// Generator seed (0 for loaded data) — recorded in EXPERIMENTS.md.
    pub seed: u64,
}

impl Dataset {
    pub fn new(name: impl Into<String>, points: Matrix, seed: u64) -> Self {
        Self { name: name.into(), points, seed }
    }

    pub fn n(&self) -> usize {
        self.points.rows()
    }

    pub fn d(&self) -> usize {
        self.points.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_validates_shape() {
        assert!(Matrix::from_vec(vec![0.0; 6], 2, 3).is_ok());
        assert!(Matrix::from_vec(vec![0.0; 5], 2, 3).is_err());
    }

    #[test]
    fn gather_rows_reorders() {
        let m = Matrix::from_vec(vec![1., 2., 3., 4., 5., 6.], 3, 2).unwrap();
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.row(0), &[5., 6.]);
        assert_eq!(g.row(1), &[1., 2.]);
    }

    #[test]
    fn padded_zero_fills() {
        let m = Matrix::from_vec(vec![1., 2., 3., 4.], 2, 2).unwrap();
        let p = m.padded(3, 4).unwrap();
        assert_eq!(p.len(), 12);
        assert_eq!(&p[0..4], &[1., 2., 0., 0.]);
        assert_eq!(&p[4..8], &[3., 4., 0., 0.]);
        assert_eq!(&p[8..12], &[0.; 4]);
        assert!(m.padded(1, 2).is_err());
    }

    #[test]
    fn dist2_is_squared_euclidean() {
        let a = Matrix::from_vec(vec![0., 0., 3., 4.], 2, 2).unwrap();
        assert_eq!(a.dist2(0, &a, 1), 25.0);
        assert_eq!(a.dist2(1, &a, 1), 0.0);
    }
}
