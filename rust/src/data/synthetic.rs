//! Synthetic dataset generators.
//!
//! Stand-ins for the paper's UCI datasets (see DESIGN.md
//! §Substitutions): mixture-of-Gaussians clusters reproduce the "real
//! data has cluster structure" property that triangle-inequality
//! filtering exploits; `uniform` gives the adversarial no-structure
//! case used in ablations; `plummer` generates the centrally-condensed
//! particle distributions typical of gravitational N-body initial
//! conditions.

use super::{Dataset, Matrix};
use crate::util::rng::Rng;

/// Mixture of `centers` Gaussians in [0,1]^d with per-cluster sigma
/// `spread`.  Density (the paper's alpha in Eq. 7) rises as `spread`
/// falls, which is exactly the knob the GTI ablation benches sweep.
pub fn clustered(n: usize, d: usize, centers: usize, spread: f32, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut mu = Matrix::zeros(centers.max(1), d);
    for c in 0..centers.max(1) {
        for k in 0..d {
            mu.row_mut(c)[k] = rng.f32();
        }
    }
    let mut pts = Matrix::zeros(n, d);
    for i in 0..n {
        let c = rng.below(centers.max(1));
        for k in 0..d {
            pts.row_mut(i)[k] = mu.row(c)[k] + spread * rng.normal();
        }
    }
    Dataset::new(format!("clustered_n{n}_d{d}_c{centers}"), pts, seed)
}

/// Uniform points in [0,1]^d — worst case for TI filtering.
pub fn uniform(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut pts = Matrix::zeros(n, d);
    for i in 0..n {
        for k in 0..d {
            pts.row_mut(i)[k] = rng.f32();
        }
    }
    Dataset::new(format!("uniform_n{n}_d{d}"), pts, seed)
}

/// Plummer-sphere particle positions (3-D), the standard N-body initial
/// condition: radius CDF r = a / sqrt(u^{-2/3} - 1).
pub fn plummer(n: usize, scale: f32, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut pts = Matrix::zeros(n, 3);
    for i in 0..n {
        // Draw radius from the Plummer cumulative mass profile.
        let u = rng.f64().max(1e-9) as f32;
        let r = scale / (u.powf(-2.0 / 3.0) - 1.0).max(1e-9).sqrt();
        let r = r.min(10.0 * scale); // clip the heavy tail
        // Uniform direction on the sphere.
        let z = rng.range_f32(-1.0, 1.0);
        let phi = rng.range_f32(0.0, 2.0 * std::f32::consts::PI);
        let s = (1.0 - z * z).max(0.0).sqrt();
        let row = pts.row_mut(i);
        row[0] = r * s * phi.cos();
        row[1] = r * s * phi.sin();
        row[2] = r * z;
    }
    Dataset::new(format!("plummer_n{n}"), pts, seed)
}

/// Particle masses for N-body runs: equal mass summing to `total`.
pub fn equal_masses(n: usize, total: f32) -> Vec<f32> {
    vec![total / n as f32; n]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clustered_shape_and_determinism() {
        let a = clustered(100, 8, 5, 0.05, 42);
        let b = clustered(100, 8, 5, 0.05, 42);
        assert_eq!(a.n(), 100);
        assert_eq!(a.d(), 8);
        assert_eq!(a.points, b.points);
        let c = clustered(100, 8, 5, 0.05, 43);
        assert_ne!(a.points, c.points);
    }

    #[test]
    fn clustered_has_tighter_structure_than_uniform() {
        // Mean nearest-neighbor distance should be markedly smaller for
        // clustered data at equal n/d — the property GTI exploits.
        let cl = clustered(300, 4, 10, 0.01, 7);
        let un = uniform(300, 4, 7);
        let mean_nn = |m: &Matrix| {
            let mut total = 0.0f64;
            for i in 0..m.rows() {
                let mut best = f32::INFINITY;
                for j in 0..m.rows() {
                    if i != j {
                        best = best.min(m.dist2(i, &m.clone(), j));
                    }
                }
                total += best as f64;
            }
            total / m.rows() as f64
        };
        assert!(mean_nn(&cl.points) < mean_nn(&un.points));
    }

    #[test]
    fn uniform_in_unit_cube() {
        let u = uniform(200, 6, 3);
        assert!(u.points.as_slice().iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn plummer_is_centrally_condensed() {
        let p = plummer(2000, 1.0, 11);
        let radii: Vec<f32> =
            (0..p.n()).map(|i| p.points.row(i).iter().map(|x| x * x).sum::<f32>().sqrt()).collect();
        let inner = radii.iter().filter(|&&r| r < 1.0).count();
        // Plummer: ~35% of mass inside the scale radius r < a.
        assert!(inner > p.n() / 5, "inner fraction too small: {inner}/{}", p.n());
    }

    #[test]
    fn equal_masses_sum() {
        let m = equal_masses(128, 1.0);
        assert!((m.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }
}
