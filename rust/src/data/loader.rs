//! CSV loader for users who have the real UCI files from Table V.
//!
//! Accepts plain numeric CSV (optional header), selects all numeric
//! columns, and ignores rows with parse failures up to a tolerance so
//! the typical UCI "mostly numeric with a label column" layout loads
//! without preprocessing.

use std::io::BufRead;
use std::path::Path;

use super::{Dataset, Matrix};
use crate::{Error, Result};

/// Options for [`load_csv`].
#[derive(Debug, Clone)]
pub struct CsvOptions {
    pub delimiter: char,
    /// Skip the first line if it fails to parse fully (header detection).
    pub allow_header: bool,
    /// Columns to drop (e.g. label columns), by index.
    pub drop_cols: Vec<usize>,
    /// Abort if more than this fraction of data rows fail to parse.
    pub max_bad_row_frac: f64,
}

impl Default for CsvOptions {
    fn default() -> Self {
        Self { delimiter: ',', allow_header: true, drop_cols: vec![], max_bad_row_frac: 0.01 }
    }
}

/// Load a numeric CSV file as a Dataset.
pub fn load_csv(path: impl AsRef<Path>, opts: &CsvOptions) -> Result<Dataset> {
    let path = path.as_ref();
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    let mut data: Vec<f32> = Vec::new();
    let mut cols: Option<usize> = None;
    let mut bad_rows = 0usize;
    let mut total_rows = 0usize;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let parsed: Vec<Option<f32>> = trimmed
            .split(opts.delimiter)
            .enumerate()
            .filter(|(i, _)| !opts.drop_cols.contains(i))
            .map(|(_, tok)| tok.trim().parse::<f32>().ok())
            .collect();
        let ok = parsed.iter().all(|p| p.is_some()) && !parsed.is_empty();
        if !ok {
            if lineno == 0 && opts.allow_header {
                continue; // header line
            }
            bad_rows += 1;
            total_rows += 1;
            continue;
        }
        let row: Vec<f32> = parsed.into_iter().map(|p| p.unwrap()).collect();
        match cols {
            None => cols = Some(row.len()),
            Some(c) if c != row.len() => {
                bad_rows += 1;
                total_rows += 1;
                continue;
            }
            _ => {}
        }
        data.extend_from_slice(&row);
        total_rows += 1;
    }

    let cols = cols.ok_or_else(|| Error::Data(format!("{}: no numeric rows", path.display())))?;
    if total_rows > 0 && (bad_rows as f64 / total_rows as f64) > opts.max_bad_row_frac {
        return Err(Error::Data(format!(
            "{}: {bad_rows}/{total_rows} rows failed to parse",
            path.display()
        )));
    }
    let rows = data.len() / cols;
    let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("csv").to_string();
    Ok(Dataset::new(name, Matrix::from_vec(data, rows, cols)?, 0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_tmp(name: &str, content: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(name);
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        p
    }

    #[test]
    fn loads_plain_csv() {
        let p = write_tmp("accd_test_plain.csv", "1.0,2.0\n3.0,4.0\n");
        let ds = load_csv(&p, &CsvOptions::default()).unwrap();
        assert_eq!((ds.n(), ds.d()), (2, 2));
        assert_eq!(ds.points.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn skips_header() {
        let p = write_tmp("accd_test_header.csv", "x,y\n1,2\n3,4\n");
        let ds = load_csv(&p, &CsvOptions::default()).unwrap();
        assert_eq!(ds.n(), 2);
    }

    #[test]
    fn drops_label_column() {
        let p = write_tmp("accd_test_label.csv", "1,2,cat\n3,4,dog\n");
        let opts = CsvOptions { drop_cols: vec![2], ..Default::default() };
        let ds = load_csv(&p, &opts).unwrap();
        assert_eq!((ds.n(), ds.d()), (2, 2));
    }

    #[test]
    fn rejects_too_many_bad_rows() {
        let p = write_tmp("accd_test_bad.csv", "1,2\nx,y\nz,w\n");
        assert!(load_csv(&p, &CsvOptions::default()).is_err());
    }
}
