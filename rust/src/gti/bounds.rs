//! The GTI bound algebra — paper §IV-B, Eqs. 1-3.
//!
//! All bounds here are *sound*: `lb <= d(a,b) <= ub` for every point
//! pair they summarise (property-tested in this module and in
//! `rust/tests/prop_coordinator.rs`).  Soundness is what lets the
//! filter discard group pairs without ever being wrong, so these few
//! lines carry the correctness of the whole optimization.

use super::grouping::Grouping;
use crate::data::Matrix;

/// Lower/upper bound on the distance between any member of a source
/// group and any member of a target group.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupPairBound {
    pub lb: f32,
    pub ub: f32,
}

impl GroupPairBound {
    /// Group-level bound (Eq. 2): from the landmark-landmark distance
    /// and both radii.
    #[inline]
    pub fn from_center_dist(center_dist: f32, r_src: f32, r_trg: f32) -> Self {
        Self {
            lb: (center_dist - r_src - r_trg).max(0.0),
            ub: center_dist + r_src + r_trg,
        }
    }

    /// Trace-based widening (Eq. 3 / Fig. 2d): both groups' contents
    /// moved by at most `drift_src` / `drift_trg` since `self` was
    /// computed, so the bound loosens additively.
    #[inline]
    pub fn widened(self, drift_src: f32, drift_trg: f32) -> Self {
        let w = drift_src + drift_trg;
        Self { lb: (self.lb - w).max(0.0), ub: self.ub + w }
    }
}

/// Two-landmark point bound (Eq. 1): `d(a_ref,b_ref)` known, each point
/// within `da`/`db` of its landmark.
#[inline]
pub fn two_landmark(d_ref: f32, da: f32, db: f32) -> GroupPairBound {
    GroupPairBound { lb: (d_ref - da - db).max(0.0), ub: d_ref + da + db }
}

/// One-landmark point bound (Fig. 2a): `d(a, l)` and `d(l, b)` known.
#[inline]
pub fn one_landmark(d_al: f32, d_lb: f32) -> GroupPairBound {
    GroupPairBound { lb: (d_al - d_lb).abs(), ub: d_al + d_lb }
}

/// Dense landmark-landmark distances + Eq. 2 bounds for every
/// (source group, target group) pair.  This is the `z_src x z_trg`
/// matrix whose small memory footprint the paper contrasts with
/// point-level TI (§IV-B-c); it is also the only O(z^2 d) work in the
/// filter, counted into `Latency_filt`.
pub fn group_pair_bounds(src: &Grouping, trg: &Grouping) -> Vec<Vec<GroupPairBound>> {
    group_pair_bounds_metric(src, trg, super::Metric::L2)
}

/// Metric-aware Eq. 2 bounds: requires groupings built with the same
/// metric (radii must be in the same units as the center distances).
pub fn group_pair_bounds_metric(
    src: &Grouping,
    trg: &Grouping,
    metric: super::Metric,
) -> Vec<Vec<GroupPairBound>> {
    let zs = src.num_groups();
    let zt = trg.num_groups();
    let mut out = Vec::with_capacity(zs);
    for a in 0..zs {
        let mut row = Vec::with_capacity(zt);
        for b in 0..zt {
            let cd = metric.dist_rows(&src.centers, a, &trg.centers, b);
            row.push(GroupPairBound::from_center_dist(cd, src.radii[a], trg.radii[b]));
        }
        out.push(row);
    }
    out
}

/// Exact center-pair distance matrix (used by the N-body trace cache).
pub fn center_distances(src: &Matrix, trg: &Matrix) -> Vec<f32> {
    let (zs, zt) = (src.rows(), trg.rows());
    let mut out = vec![0.0f32; zs * zt];
    for a in 0..zs {
        for b in 0..zt {
            out[a * zt + b] = src.dist2(a, trg, b).sqrt();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::gti::grouping::Grouping;
    use crate::util::prop;

    #[test]
    fn eq2_bounds_are_sound_on_real_grouping() {
        let s = synthetic::clustered(200, 5, 6, 0.05, 1);
        let t = synthetic::clustered(150, 5, 4, 0.05, 2);
        let gs = Grouping::build(&s.points, 8, 2, 200, 3).unwrap();
        let gt = Grouping::build(&t.points, 6, 2, 150, 4).unwrap();
        let bounds = group_pair_bounds(&gs, &gt);
        for (a, mem_a) in gs.members.iter().enumerate() {
            for (b, mem_b) in gt.members.iter().enumerate() {
                let bd = bounds[a][b];
                for &i in mem_a.iter().take(5) {
                    for &j in mem_b.iter().take(5) {
                        let d = s.points.dist2(i as usize, &t.points, j as usize).sqrt();
                        assert!(
                            bd.lb <= d * 1.0001 + 1e-4 && d <= bd.ub * 1.0001 + 1e-4,
                            "bound [{}, {}] violated by d={d} (groups {a},{b})",
                            bd.lb,
                            bd.ub
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn widened_never_tightens() {
        let b = GroupPairBound { lb: 2.0, ub: 5.0 };
        let w = b.widened(0.5, 0.25);
        assert!(w.lb <= b.lb && w.ub >= b.ub);
        assert_eq!(w.lb, 1.25);
        assert_eq!(w.ub, 5.75);
        // lb clamps at zero
        assert_eq!(b.widened(10.0, 0.0).lb, 0.0);
    }

    #[test]
    fn two_landmark_matches_eq1() {
        let b = two_landmark(10.0, 2.0, 3.0);
        assert_eq!(b.lb, 5.0);
        assert_eq!(b.ub, 15.0);
    }

    #[test]
    fn one_landmark_reverse_triangle() {
        let b = one_landmark(7.0, 3.0);
        assert_eq!(b.lb, 4.0);
        assert_eq!(b.ub, 10.0);
    }

    #[test]
    fn prop_two_landmark_soundness_in_euclidean_plane() {
        // Random planar points: a, b with landmarks la, lb — Eq. 1 must
        // bound the true distance.
        prop::check(
            &prop::Config { cases: 64, max_size: 100, ..Default::default() },
            |rng, _| {
                let p: Vec<f32> = (0..8).map(|_| rng.range_f32(-10.0, 10.0)).collect();
                p
            },
            |p| {
                let d = |i: usize, j: usize| {
                    let (dx, dy) = (p[2 * i] - p[2 * j], p[2 * i + 1] - p[2 * j + 1]);
                    (dx * dx + dy * dy).sqrt()
                };
                // points: 0=a, 1=b, 2=la, 3=lb
                let bound = two_landmark(d(2, 3), d(0, 2), d(1, 3));
                let dist = d(0, 1);
                if bound.lb <= dist + 1e-4 && dist <= bound.ub + 1e-4 {
                    Ok(())
                } else {
                    Err(format!("bound [{},{}] misses d={dist}", bound.lb, bound.ub))
                }
            },
        );
    }
}
