//! The GTI bound algebra — paper §IV-B, Eqs. 1-3 — plus the
//! *incremental* Elkan/Hamerly extension the K-means program carries
//! across iterations: per-point upper/lower bounds and group-pair
//! lower bounds are tightened once (plan time) and then widened O(1)
//! per step by per-center drift ([`DriftWidening`],
//! [`widen_point_bounds`], [`widen_pair_lbs`]) instead of recomputed.
//!
//! All bounds here are *sound*: `lb <= d(a,b) <= ub` for every point
//! pair they summarise (property-tested in this module and in
//! `rust/tests/prop_coordinator.rs` / `rust/tests/prop_gti_bounds.rs`).
//! Soundness is what lets the filter discard group pairs — and the
//! incremental path skip stable points and whole tiles — without ever
//! being wrong, so these few lines carry the correctness of the whole
//! optimization.

use super::grouping::Grouping;
use crate::data::Matrix;

/// Lower/upper bound on the distance between any member of a source
/// group and any member of a target group.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupPairBound {
    pub lb: f32,
    pub ub: f32,
}

impl GroupPairBound {
    /// Group-level bound (Eq. 2): from the landmark-landmark distance
    /// and both radii.
    #[inline]
    pub fn from_center_dist(center_dist: f32, r_src: f32, r_trg: f32) -> Self {
        Self {
            lb: (center_dist - r_src - r_trg).max(0.0),
            ub: center_dist + r_src + r_trg,
        }
    }

    /// Trace-based widening (Eq. 3 / Fig. 2d): both groups' contents
    /// moved by at most `drift_src` / `drift_trg` since `self` was
    /// computed, so the bound loosens additively.
    #[inline]
    pub fn widened(self, drift_src: f32, drift_trg: f32) -> Self {
        let w = drift_src + drift_trg;
        Self { lb: (self.lb - w).max(0.0), ub: self.ub + w }
    }
}

/// Two-landmark point bound (Eq. 1): `d(a_ref,b_ref)` known, each point
/// within `da`/`db` of its landmark.
#[inline]
pub fn two_landmark(d_ref: f32, da: f32, db: f32) -> GroupPairBound {
    GroupPairBound { lb: (d_ref - da - db).max(0.0), ub: d_ref + da + db }
}

/// One-landmark point bound (Fig. 2a): `d(a, l)` and `d(l, b)` known.
#[inline]
pub fn one_landmark(d_al: f32, d_lb: f32) -> GroupPairBound {
    GroupPairBound { lb: (d_al - d_lb).abs(), ub: d_al + d_lb }
}

/// Dense landmark-landmark distances + Eq. 2 bounds for every
/// (source group, target group) pair.  This is the `z_src x z_trg`
/// matrix whose small memory footprint the paper contrasts with
/// point-level TI (§IV-B-c); it is also the only O(z^2 d) work in the
/// filter, counted into `Latency_filt`.
pub fn group_pair_bounds(src: &Grouping, trg: &Grouping) -> Vec<Vec<GroupPairBound>> {
    group_pair_bounds_metric(src, trg, super::Metric::L2)
}

/// Metric-aware Eq. 2 bounds: requires groupings built with the same
/// metric (radii must be in the same units as the center distances).
pub fn group_pair_bounds_metric(
    src: &Grouping,
    trg: &Grouping,
    metric: super::Metric,
) -> Vec<Vec<GroupPairBound>> {
    let zs = src.num_groups();
    let zt = trg.num_groups();
    let mut out = Vec::with_capacity(zs);
    for a in 0..zs {
        let mut row = Vec::with_capacity(zt);
        for b in 0..zt {
            let cd = metric.dist_rows(&src.centers, a, &trg.centers, b);
            row.push(GroupPairBound::from_center_dist(cd, src.radii[a], trg.radii[b]));
        }
        out.push(row);
    }
    out
}

/// Per-step drift summary for the O(1) Hamerly widening rule.
///
/// A point assigned to center `a` needs two numbers each iteration:
/// `drift[a]` (its upper bound loosens by exactly that) and
/// `max_other(a)` — the largest drift among *all other* centers (its
/// lower bound to the second-closest center can shrink by at most
/// that).  Precomputing the global max / argmax / second-max once per
/// step makes `max_other` O(1) per point instead of O(k).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftWidening {
    /// Largest per-center drift this step.
    pub max: f32,
    /// Center index holding `max` (`usize::MAX` when every drift is 0).
    pub argmax: usize,
    /// Second-largest per-center drift (ties with `max` repeat it).
    pub second: f32,
}

impl DriftWidening {
    /// Summarise one step's per-center drifts.
    #[must_use]
    pub fn from_drifts(drifts: &[f32]) -> Self {
        let mut max = 0.0f32;
        let mut argmax = usize::MAX;
        let mut second = 0.0f32;
        for (c, &d) in drifts.iter().enumerate() {
            if d > max {
                second = max;
                max = d;
                argmax = c;
            } else if d > second {
                second = d;
            }
        }
        Self { max, argmax, second }
    }

    /// Largest drift among centers other than `assigned` — the sound
    /// per-step shrink of a point's lower bound to its second-closest
    /// center.
    #[inline]
    #[must_use]
    pub fn max_other(&self, assigned: usize) -> f32 {
        if assigned == self.argmax {
            self.second
        } else {
            self.max
        }
    }
}

/// Hamerly widening of the per-point bounds after one step of center
/// motion: `ub[i]` loosens by its own center's drift, `lb[i]` (the
/// lower bound to the closest *non-assigned* center) shrinks by the
/// largest drift any other center made.  Assignments are indices into
/// `drift`; an `INFINITY` lower bound (single real center) stays
/// infinite.
pub fn widen_point_bounds(
    ub: &mut [f32],
    lb: &mut [f32],
    assign: &[u32],
    drift: &[f32],
    w: &DriftWidening,
) {
    for i in 0..assign.len() {
        let a = assign[i] as usize;
        ub[i] += drift[a];
        lb[i] = (lb[i] - w.max_other(a)).max(0.0);
    }
}

/// Max *member* drift per center group: the sound widening amount for
/// a (source group x center group) lower bound when source points are
/// fixed and only centers move.  Note this is NOT the drift of the
/// group's landmark (a centroid can move far less than its farthest
/// member), which is why the group-pair widening must take per-center
/// drifts, not `Grouping::recenter`'s landmark drift.
#[must_use]
pub fn center_group_drift(cg_assign: &[u32], z_trg: usize, drift: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; z_trg];
    for (c, &d) in drift.iter().enumerate() {
        let b = cg_assign[c] as usize;
        if d > out[b] {
            out[b] = d;
        }
    }
    out
}

/// Widen a `z_src x z_trg` matrix of group-pair *lower* bounds by the
/// per-center-group max member drift (source side fixed).  Lower
/// bounds clamp at zero; there is no upper-bound counterpart because
/// the incremental filter only ever prunes on `lb > ub_point`.
pub fn widen_pair_lbs(pair_lb: &mut [Vec<f32>], cg_drift: &[f32]) {
    for row in pair_lb.iter_mut() {
        for (b, l) in row.iter_mut().enumerate() {
            *l = (*l - cg_drift[b]).max(0.0);
        }
    }
}

/// Exact center-pair distance matrix (used by the N-body trace cache).
pub fn center_distances(src: &Matrix, trg: &Matrix) -> Vec<f32> {
    let (zs, zt) = (src.rows(), trg.rows());
    let mut out = vec![0.0f32; zs * zt];
    for a in 0..zs {
        for b in 0..zt {
            out[a * zt + b] = src.dist2(a, trg, b).sqrt();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::gti::grouping::Grouping;
    use crate::util::prop;

    #[test]
    fn eq2_bounds_are_sound_on_real_grouping() {
        let s = synthetic::clustered(200, 5, 6, 0.05, 1);
        let t = synthetic::clustered(150, 5, 4, 0.05, 2);
        let gs = Grouping::build(&s.points, 8, 2, 200, 3).unwrap();
        let gt = Grouping::build(&t.points, 6, 2, 150, 4).unwrap();
        let bounds = group_pair_bounds(&gs, &gt);
        for (a, mem_a) in gs.members.iter().enumerate() {
            for (b, mem_b) in gt.members.iter().enumerate() {
                let bd = bounds[a][b];
                for &i in mem_a.iter().take(5) {
                    for &j in mem_b.iter().take(5) {
                        let d = s.points.dist2(i as usize, &t.points, j as usize).sqrt();
                        assert!(
                            bd.lb <= d * 1.0001 + 1e-4 && d <= bd.ub * 1.0001 + 1e-4,
                            "bound [{}, {}] violated by d={d} (groups {a},{b})",
                            bd.lb,
                            bd.ub
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn widened_never_tightens() {
        let b = GroupPairBound { lb: 2.0, ub: 5.0 };
        let w = b.widened(0.5, 0.25);
        assert!(w.lb <= b.lb && w.ub >= b.ub);
        assert_eq!(w.lb, 1.25);
        assert_eq!(w.ub, 5.75);
        // lb clamps at zero
        assert_eq!(b.widened(10.0, 0.0).lb, 0.0);
    }

    #[test]
    fn two_landmark_matches_eq1() {
        let b = two_landmark(10.0, 2.0, 3.0);
        assert_eq!(b.lb, 5.0);
        assert_eq!(b.ub, 15.0);
    }

    #[test]
    fn one_landmark_reverse_triangle() {
        let b = one_landmark(7.0, 3.0);
        assert_eq!(b.lb, 4.0);
        assert_eq!(b.ub, 10.0);
    }

    #[test]
    fn drift_widening_tracks_max_and_second() {
        let w = DriftWidening::from_drifts(&[0.1, 0.5, 0.3]);
        assert_eq!(w.max, 0.5);
        assert_eq!(w.argmax, 1);
        assert_eq!(w.second, 0.3);
        assert_eq!(w.max_other(1), 0.3, "holder of the max sees the second-max");
        assert_eq!(w.max_other(0), 0.5);
        assert_eq!(w.max_other(2), 0.5);
        // Tied maxima: everyone sees the full max.
        let w = DriftWidening::from_drifts(&[0.5, 0.5]);
        assert_eq!(w.max_other(0), 0.5);
        assert_eq!(w.max_other(1), 0.5);
        // Single center: no other center ever moves.
        let w = DriftWidening::from_drifts(&[0.7]);
        assert_eq!(w.max_other(0), 0.0);
        // All-zero drifts: argmax sentinel, max_other is 0 everywhere.
        let w = DriftWidening::from_drifts(&[0.0, 0.0]);
        assert_eq!(w.max_other(0), 0.0);
        assert_eq!(w.max_other(1), 0.0);
    }

    #[test]
    fn widen_point_bounds_applies_hamerly_rule() {
        let drift = [0.2f32, 0.05];
        let w = DriftWidening::from_drifts(&drift);
        let mut ub = vec![1.0f32, 2.0];
        let mut lb = vec![3.0f32, 0.1];
        let assign = vec![0u32, 1];
        widen_point_bounds(&mut ub, &mut lb, &assign, &drift, &w);
        // Point 0 (center 0): ub += 0.2, lb -= max_other(0) = 0.05.
        assert!((ub[0] - 1.2).abs() < 1e-6);
        assert!((lb[0] - 2.95).abs() < 1e-6);
        // Point 1 (center 1): ub += 0.05, lb -= 0.2 clamped at 0.
        assert!((ub[1] - 2.05).abs() < 1e-6);
        assert_eq!(lb[1], 0.0);
        // INFINITY lower bounds survive widening.
        let mut lb_inf = vec![f32::INFINITY];
        let mut ub1 = vec![1.0f32];
        widen_point_bounds(&mut ub1, &mut lb_inf, &[0], &drift, &w);
        assert!(lb_inf[0].is_infinite());
    }

    #[test]
    fn center_group_drift_is_max_member_drift() {
        // Centers 0,2 in group 0; center 1 in group 1.
        let cg_assign = vec![0u32, 1, 0];
        let m = center_group_drift(&cg_assign, 2, &[0.1, 0.4, 0.3]);
        assert_eq!(m, vec![0.3, 0.4]);
        // Empty group keeps zero drift.
        let m = center_group_drift(&[0u32], 2, &[0.2]);
        assert_eq!(m, vec![0.2, 0.0]);
    }

    #[test]
    fn widen_pair_lbs_shrinks_and_clamps() {
        let mut pair = vec![vec![1.0f32, 0.2], vec![0.5, 2.0]];
        widen_pair_lbs(&mut pair, &[0.3, 0.4]);
        assert!((pair[0][0] - 0.7).abs() < 1e-6);
        assert_eq!(pair[0][1], 0.0, "lb clamps at zero");
        assert!((pair[1][0] - 0.2).abs() < 1e-6);
        assert!((pair[1][1] - 1.6).abs() < 1e-6);
    }

    #[test]
    fn prop_two_landmark_soundness_in_euclidean_plane() {
        // Random planar points: a, b with landmarks la, lb — Eq. 1 must
        // bound the true distance.
        prop::check(
            &prop::Config { cases: 64, max_size: 100, ..Default::default() },
            |rng, _| {
                let p: Vec<f32> = (0..8).map(|_| rng.range_f32(-10.0, 10.0)).collect();
                p
            },
            |p| {
                let d = |i: usize, j: usize| {
                    let (dx, dy) = (p[2 * i] - p[2 * j], p[2 * i + 1] - p[2 * j + 1]);
                    (dx * dx + dy * dy).sqrt()
                };
                // points: 0=a, 1=b, 2=la, 3=lb
                let bound = two_landmark(d(2, 3), d(0, 2), d(1, 3));
                let dist = d(0, 1);
                if bound.lb <= dist + 1e-4 && dist <= bound.ub + 1e-4 {
                    Ok(())
                } else {
                    Err(format!("bound [{},{}] misses d={dist}", bound.lb, bound.ub))
                }
            },
        );
    }
}
