//! Per-algorithm GTI candidate filters — the CPU half of the co-design.
//!
//! Each filter consumes group-level bounds and produces, per source
//! group, the list of target groups whose distances must actually be
//! computed.  Surviving pairs keep full regularity: every point of the
//! source group is paired with every point of each candidate group
//! (Fig. 3b), which is what makes the accelerator tiles dense.
//!
//! The filters also keep running [`FilterStats`] so benches can report
//! the paper's `ratio_save` and bound-computation overheads.

use super::bounds::{group_pair_bounds, GroupPairBound};
use super::grouping::Grouping;

/// Counters describing one filtering pass.
#[derive(Debug, Clone, Default)]
pub struct FilterStats {
    /// Distance computations the unoptimized algorithm would perform.
    pub total_pairs: u64,
    /// Point-pair distance computations that survived filtering.
    pub surviving_pairs: u64,
    /// Bound computations performed (the GTI overhead term).
    pub bound_comps: u64,
    /// Group pairs evaluated / surviving.
    pub group_pairs: u64,
    pub surviving_group_pairs: u64,
    /// Candidate (source group x center group) rectangles skipped
    /// entirely because every member was proven stable (incremental TI).
    pub tiles_skipped: u64,
    /// Point rows excluded from device submissions because their
    /// assignment was proven stable (incremental TI).
    pub points_pruned: u64,
    /// Per-point exact bound re-tightenings performed on the CPU by the
    /// incremental TI stability test (its overhead term).
    pub bound_recomputes: u64,
}

impl FilterStats {
    /// Fraction of distance computations eliminated (paper `1 - ratio_save`
    /// is reported as "saving"; we report the surviving ratio).
    pub fn surviving_ratio(&self) -> f64 {
        if self.total_pairs == 0 {
            1.0
        } else {
            self.surviving_pairs as f64 / self.total_pairs as f64
        }
    }

    pub fn saving_ratio(&self) -> f64 {
        1.0 - self.surviving_ratio()
    }

    pub fn merge(&mut self, other: &FilterStats) {
        self.total_pairs += other.total_pairs;
        self.surviving_pairs += other.surviving_pairs;
        self.bound_comps += other.bound_comps;
        self.group_pairs += other.group_pairs;
        self.surviving_group_pairs += other.surviving_group_pairs;
        self.tiles_skipped += other.tiles_skipped;
        self.points_pruned += other.points_pruned;
        self.bound_recomputes += other.bound_recomputes;
    }
}

/// Tile-granular stability: split a source group's members into the
/// rows that still need a device recompute and the count of rows whose
/// assignment is provably stable (`ub[i] <= lb[i]`).  An empty unstable
/// list means the whole (group x candidate centers) rectangle can be
/// dropped from the device submission — the incremental TI path's tile
/// skip.  Bounds are indexed by *packed* point id, like `members`.
#[must_use]
pub fn unstable_members(members: &[u32], ub: &[f32], lb: &[f32]) -> (Vec<u32>, u64) {
    let mut unstable = Vec::new();
    let mut stable = 0u64;
    for &pi in members {
        let i = pi as usize;
        if ub[i] <= lb[i] {
            stable += 1;
        } else {
            unstable.push(pi);
        }
    }
    (unstable, stable)
}

/// Candidate target groups for each source group.
pub type Candidates = Vec<Vec<u32>>;

// ---------------------------------------------------------------------------
// K-means: Trace-based + Group-level (paper §VII intro)
// ---------------------------------------------------------------------------

/// K-means filter state.
///
/// Source points are grouped once (membership never changes); the k
/// cluster centers are grouped into `z_trg` center-groups.  Per
/// (source group, center group) we keep an Eq. 2 lower bound and per
/// source group an upper bound on "worst distance from any member to
/// its currently assigned center".  After each center update the
/// bounds are *widened* by the center drifts (trace-based, Fig. 2c)
/// instead of recomputed — recomputation happens lazily only for
/// source groups that fail the prune test.
#[derive(Debug)]
pub struct KmeansFilter {
    /// lb\[src_group\]\[center_group\]
    lb: Vec<Vec<f32>>,
    /// Per source group: upper bound on max member->assigned-center dist.
    ub: Vec<f32>,
    pub stats: FilterStats,
}

impl KmeansFilter {
    /// Initialize from the first full assignment round.
    ///
    /// `per_point_best` is each point's exact distance to its assigned
    /// center from the initial full computation; group-level ub is the
    /// max over members.  Lower bounds start from Eq. 2 on the center
    /// grouping.
    pub fn new(
        src: &Grouping,
        center_groups: &Grouping,
        per_point_best: &[f32],
    ) -> Self {
        let zs = src.num_groups();
        let zt = center_groups.num_groups();
        let pair_bounds = group_pair_bounds(src, center_groups);
        let mut lb = vec![vec![0.0f32; zt]; zs];
        for a in 0..zs {
            for b in 0..zt {
                lb[a][b] = pair_bounds[a][b].lb;
            }
        }
        let mut ub = vec![0.0f32; zs];
        for (pi, &gi) in src.assign.iter().enumerate() {
            let d = per_point_best[pi].sqrt();
            if d > ub[gi as usize] {
                ub[gi as usize] = d;
            }
        }
        let stats = FilterStats {
            bound_comps: (zs * zt) as u64,
            ..Default::default()
        };
        Self { lb, ub, stats }
    }

    /// Apply one center-update round: widen bounds by group drift
    /// (trace-based).  `center_group_drift[b]` = max drift of centers in
    /// group b; `assigned_drift[a]` = max drift of any center currently
    /// assigned to a member of source group a.
    pub fn apply_drift(&mut self, center_group_drift: &[f32], assigned_drift: &[f32]) {
        for (a, row) in self.lb.iter_mut().enumerate() {
            self.ub[a] += assigned_drift[a];
            for (b, l) in row.iter_mut().enumerate() {
                *l = (*l - center_group_drift[b]).max(0.0);
            }
            self.stats.bound_comps += row.len() as u64 + 1;
        }
    }

    /// Candidate center-groups per source group: group b survives for
    /// source group a iff `lb[a][b] <= ub[a]` — otherwise *no* member of
    /// a can have its nearest center inside b.
    ///
    /// `group_sizes` are center-group member counts (for stats);
    /// `src_sizes` source-group member counts.
    pub fn candidates(
        &mut self,
        src_sizes: &[usize],
        center_group_sizes: &[usize],
        total_centers: usize,
    ) -> Candidates {
        let zs = self.lb.len();
        let mut out = Vec::with_capacity(zs);
        for a in 0..zs {
            let mut cand = Vec::new();
            for (b, &l) in self.lb[a].iter().enumerate() {
                self.stats.group_pairs += 1;
                if l <= self.ub[a] {
                    cand.push(b as u32);
                    self.stats.surviving_group_pairs += 1;
                    self.stats.surviving_pairs +=
                        (src_sizes[a] * center_group_sizes[b]) as u64;
                }
            }
            self.stats.total_pairs += (src_sizes[a] * total_centers) as u64;
            out.push(cand);
        }
        out
    }

    /// After exact recomputation of a source group, refresh its bounds.
    pub fn refresh_group(&mut self, a: usize, new_ub: f32, new_lb: &[f32]) {
        self.ub[a] = new_ub;
        self.lb[a].copy_from_slice(new_lb);
        self.stats.bound_comps += new_lb.len() as u64 + 1;
    }

    pub fn ub(&self, a: usize) -> f32 {
        self.ub[a]
    }

    pub fn lb_row(&self, a: usize) -> &[f32] {
        &self.lb[a]
    }
}

// ---------------------------------------------------------------------------
// KNN-join: Two-landmark + Group-level
// ---------------------------------------------------------------------------

/// KNN-join filter: per source group, selects target groups that can
/// possibly contain one of the Top-K neighbors of *some* member.
///
/// Strategy (Eq. 2 + K-coverage threshold): sort target groups by
/// upper bound, accumulate member counts until >= K — the K-th
/// neighbor of any member is at distance <= tau (the last accumulated
/// ub).  Every target group with `lb > tau` is pruned.
pub struct KnnFilter {
    pub stats: FilterStats,
}

impl KnnFilter {
    pub fn new() -> Self {
        Self { stats: FilterStats::default() }
    }

    pub fn candidates(
        &mut self,
        src: &Grouping,
        trg: &Grouping,
        k: usize,
    ) -> (Candidates, Vec<Vec<GroupPairBound>>) {
        self.candidates_metric(src, trg, k, super::Metric::L2)
    }

    /// Metric-aware candidate selection (groupings must be built with
    /// the same metric so radii/center distances share units).
    pub fn candidates_metric(
        &mut self,
        src: &Grouping,
        trg: &Grouping,
        k: usize,
        metric: super::Metric,
    ) -> (Candidates, Vec<Vec<GroupPairBound>>) {
        let bounds = super::bounds::group_pair_bounds_metric(src, trg, metric);
        let zs = src.num_groups();
        let zt = trg.num_groups();
        self.stats.bound_comps += (zs * zt) as u64;
        let trg_sizes: Vec<usize> = trg.members.iter().map(Vec::len).collect();
        let n_trg_total: usize = trg_sizes.iter().sum();
        let mut out = Vec::with_capacity(zs);
        for a in 0..zs {
            // Coverage threshold tau.
            let mut order: Vec<u32> = (0..zt as u32).collect();
            order.sort_by(|&x, &y| {
                bounds[a][x as usize].ub.partial_cmp(&bounds[a][y as usize].ub).unwrap()
            });
            let mut covered = 0usize;
            let mut tau = f32::INFINITY;
            for &b in &order {
                covered += trg_sizes[b as usize];
                if covered >= k {
                    tau = bounds[a][b as usize].ub;
                    break;
                }
            }
            // Prune by lb > tau.
            let mut cand: Vec<u32> = Vec::new();
            for b in 0..zt {
                self.stats.group_pairs += 1;
                if bounds[a][b].lb <= tau {
                    cand.push(b as u32);
                    self.stats.surviving_group_pairs += 1;
                    self.stats.surviving_pairs +=
                        (src.members[a].len() * trg_sizes[b]) as u64;
                }
            }
            self.stats.total_pairs += (src.members[a].len() * n_trg_total) as u64;
            out.push(cand);
        }
        (out, bounds)
    }
}

impl Default for KnnFilter {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------------
// N-body: Two-landmark + Trace-based + Group-level
// ---------------------------------------------------------------------------

/// N-body radius filter with trace-based reuse across time steps.
///
/// Groups are built once over the particles; per step, group centers
/// and radii move.  Center-pair distances are computed exactly at
/// step 0 and thereafter *widened* by accumulated drift (Fig. 2d);
/// pairs whose widened lb exceeds the interaction radius R are pruned
/// without touching point data.  When accumulated drift exceeds
/// `refresh_frac * R` the exact center distances are recomputed (cheap:
/// z^2 scalar distances).
pub struct NbodyFilter {
    /// Exact center distances at last refresh, (z*z) row-major.
    center_dist: Vec<f32>,
    /// Accumulated drift per group since last refresh.
    accum_drift: Vec<f32>,
    z: usize,
    refresh_frac: f32,
    pub stats: FilterStats,
    pub refreshes: u64,
}

impl NbodyFilter {
    pub fn new(grouping: &Grouping, refresh_frac: f32) -> Self {
        let z = grouping.num_groups();
        let center_dist = super::bounds::center_distances(&grouping.centers, &grouping.centers);
        Self {
            center_dist,
            accum_drift: vec![0.0; z],
            z,
            refresh_frac,
            stats: FilterStats { bound_comps: (z * z) as u64, ..Default::default() },
            refreshes: 0,
        }
    }

    /// Advance one step: accumulate drifts, refresh exact center
    /// distances if the bound got too loose for radius `r`.
    pub fn step(&mut self, grouping: &Grouping, drifts: &[f32], r: f32) {
        for (a, &d) in drifts.iter().enumerate() {
            self.accum_drift[a] += d;
        }
        let max_drift = self.accum_drift.iter().cloned().fold(0.0f32, f32::max);
        if max_drift > self.refresh_frac * r {
            self.center_dist =
                super::bounds::center_distances(&grouping.centers, &grouping.centers);
            self.accum_drift.iter_mut().for_each(|d| *d = 0.0);
            self.stats.bound_comps += (self.z * self.z) as u64;
            self.refreshes += 1;
        }
    }

    /// Interacting group pairs for radius `r`: pair (a,b) survives iff
    /// the widened lower bound is <= r.
    pub fn candidates(&mut self, grouping: &Grouping, r: f32) -> Candidates {
        let z = self.z;
        let sizes: Vec<usize> = grouping.members.iter().map(Vec::len).collect();
        let n_total: usize = sizes.iter().sum();
        let mut out = Vec::with_capacity(z);
        for a in 0..z {
            let mut cand = Vec::new();
            for b in 0..z {
                self.stats.group_pairs += 1;
                let bound = GroupPairBound::from_center_dist(
                    self.center_dist[a * z + b],
                    grouping.radii[a],
                    grouping.radii[b],
                )
                .widened(self.accum_drift[a], self.accum_drift[b]);
                self.stats.bound_comps += 1;
                if bound.lb <= r {
                    cand.push(b as u32);
                    self.stats.surviving_group_pairs += 1;
                    self.stats.surviving_pairs += (sizes[a] * sizes[b]) as u64;
                }
            }
            self.stats.total_pairs += (sizes[a] * n_total) as u64;
            out.push(cand);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn small_grouping(n: usize, d: usize, g: usize, seed: u64) -> (crate::data::Matrix, Grouping) {
        let ds = synthetic::clustered(n, d, 6, 0.03, seed);
        let grouping = Grouping::build(&ds.points, g, 2, n, seed + 1).unwrap();
        (ds.points, grouping)
    }

    #[test]
    fn knn_filter_keeps_enough_coverage() {
        let (_s, gs) = small_grouping(300, 4, 8, 1);
        let (_t, gt) = small_grouping(400, 4, 10, 2);
        let mut f = KnnFilter::new();
        let k = 50;
        let (cands, _) = f.candidates(&gs, &gt, k);
        // Every source group must keep at least K candidate target points.
        for (a, cand) in cands.iter().enumerate() {
            let covered: usize = cand.iter().map(|&b| gt.members[b as usize].len()).sum();
            assert!(covered >= k, "group {a} covers only {covered} < {k}");
        }
        assert!(f.stats.surviving_ratio() <= 1.0);
    }

    #[test]
    fn knn_filter_prunes_on_clustered_data() {
        let (_s, gs) = small_grouping(600, 4, 16, 3);
        let (_t, gt) = small_grouping(600, 4, 16, 4);
        let mut f = KnnFilter::new();
        let (_cands, _) = f.candidates(&gs, &gt, 5);
        assert!(
            f.stats.saving_ratio() > 0.2,
            "expected >20% saving on clustered data, got {:.3}",
            f.stats.saving_ratio()
        );
    }

    #[test]
    fn nbody_filter_is_symmetric_and_reflexive() {
        let (_p, g) = small_grouping(400, 3, 10, 5);
        let mut f = NbodyFilter::new(&g, 0.5);
        let cands = f.candidates(&g, 0.3);
        // Reflexive: every non-empty group interacts with itself (lb=0).
        for (a, cand) in cands.iter().enumerate() {
            if !g.members[a].is_empty() {
                assert!(cand.contains(&(a as u32)), "group {a} missing self-pair");
            }
        }
        // Symmetric: b in cand[a] iff a in cand[b] (same bound formula).
        for (a, cand) in cands.iter().enumerate() {
            for &b in cand {
                assert!(cands[b as usize].contains(&(a as u32)));
            }
        }
    }

    #[test]
    fn nbody_drift_accumulates_then_refreshes() {
        let (p, mut g) = small_grouping(200, 3, 6, 7);
        let mut f = NbodyFilter::new(&g, 0.5);
        let r = 0.2;
        // Small drift: widen only.
        f.step(&g, &vec![0.01; 6], r);
        assert_eq!(f.refreshes, 0);
        assert!(f.accum_drift.iter().all(|&d| d > 0.0));
        // Large drift: forces refresh.
        g.refresh_radii(&p);
        f.step(&g, &vec![r; 6], r);
        assert_eq!(f.refreshes, 1);
        assert!(f.accum_drift.iter().all(|&d| d == 0.0));
    }

    #[test]
    fn unstable_members_splits_by_stability_rule() {
        // Packed ids 1,3,4; bounds indexed by packed id.
        let members = vec![1u32, 3, 4];
        let ub = vec![9.0f32, 0.5, 9.0, 2.0, 1.0];
        let lb = vec![0.0f32, 1.0, 0.0, 2.0, 0.5];
        let (unstable, stable) = unstable_members(&members, &ub, &lb);
        // id 1: 0.5 <= 1.0 stable; id 3: 2.0 <= 2.0 stable (boundary);
        // id 4: 1.0 > 0.5 unstable.
        assert_eq!(unstable, vec![4]);
        assert_eq!(stable, 2);
        // Fully-stable group -> empty unstable list (the tile skip).
        let (unstable, stable) = unstable_members(&[1, 3], &ub, &lb);
        assert!(unstable.is_empty());
        assert_eq!(stable, 2);
    }

    #[test]
    fn filter_stats_merge_covers_incremental_counters() {
        let mut a = FilterStats { tiles_skipped: 2, points_pruned: 10, ..Default::default() };
        let b = FilterStats {
            tiles_skipped: 3,
            points_pruned: 5,
            bound_recomputes: 7,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.tiles_skipped, 5);
        assert_eq!(a.points_pruned, 15);
        assert_eq!(a.bound_recomputes, 7);
    }

    #[test]
    fn kmeans_filter_drift_widens_bounds() {
        let (_p, gs) = small_grouping(300, 4, 8, 9);
        let centers = synthetic::clustered(32, 4, 4, 0.05, 10);
        let gc = Grouping::build(&centers.points, 4, 2, 32, 11).unwrap();
        let per_point_best = vec![0.04f32; 300]; // d^2 = 0.04 -> d = 0.2
        let mut f = KmeansFilter::new(&gs, &gc, &per_point_best);
        let ub0 = f.ub(0);
        let lb0 = f.lb_row(0).to_vec();
        f.apply_drift(&vec![0.1; 4], &vec![0.05; 8]);
        assert!(f.ub(0) > ub0);
        for (b, &l) in f.lb_row(0).iter().enumerate() {
            assert!(l <= lb0[b]);
        }
    }

    #[test]
    fn kmeans_candidates_never_empty_for_nonempty_groups() {
        let (_p, gs) = small_grouping(300, 4, 8, 12);
        let centers = synthetic::clustered(32, 4, 4, 0.05, 13);
        let gc = Grouping::build(&centers.points, 4, 2, 32, 14).unwrap();
        // ub derived from real distances: use a generous constant.
        let per_point_best = vec![1.0f32; 300];
        let mut f = KmeansFilter::new(&gs, &gc, &per_point_best);
        let src_sizes: Vec<usize> = gs.members.iter().map(Vec::len).collect();
        let cg_sizes: Vec<usize> = gc.members.iter().map(Vec::len).collect();
        let cands = f.candidates(&src_sizes, &cg_sizes, 32);
        for (a, c) in cands.iter().enumerate() {
            if !gs.members[a].is_empty() {
                assert!(!c.is_empty(), "source group {a} has no candidate center group");
            }
        }
    }
}
