//! Distance metrics (paper Table I: `mtr` field).
//!
//! GTI soundness only needs the triangle inequality, so the whole
//! filter stack is metric-generic: groupings carry radii in *metric*
//! units, bounds compare metric units, and only the device boundary
//! translates to/from the accelerator's native value space (squared
//! distances for L2 — cheaper on hardware — and plain sums for L1).

use crate::data::Matrix;

/// A distance metric satisfying the triangle inequality.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Metric {
    /// Euclidean. Device tiles compute the *square* (Eq. 4).
    #[default]
    L2,
    /// Manhattan / city-block.
    L1,
}

impl Metric {
    /// Parse a DDSL metric string ("L1", "L2", "Unweighted L1", ...).
    pub fn from_ddsl(norm: &str) -> Metric {
        if norm.to_ascii_lowercase().contains("l1") {
            Metric::L1
        } else {
            Metric::L2
        }
    }

    /// True metric distance between two equal-length vectors.
    #[inline]
    pub fn dist(&self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        match self {
            Metric::L2 => {
                let mut s = 0.0f32;
                for k in 0..a.len() {
                    let d = a[k] - b[k];
                    s += d * d;
                }
                s.sqrt()
            }
            Metric::L1 => {
                let mut s = 0.0f32;
                for k in 0..a.len() {
                    s += (a[k] - b[k]).abs();
                }
                s
            }
        }
    }

    /// Metric distance between matrix rows.
    #[inline]
    pub fn dist_rows(&self, a: &Matrix, i: usize, b: &Matrix, j: usize) -> f32 {
        self.dist(a.row(i), b.row(j))
    }

    /// Name of the device kernel family for this metric.
    pub fn device_name(&self) -> &'static str {
        match self {
            Metric::L2 => "l2sq",
            Metric::L1 => "l1",
        }
    }

    /// Convert a device-space value (what the tile outputs) to metric
    /// units.  L2 tiles output squared distances.
    #[inline]
    pub fn from_device(&self, v: f32) -> f32 {
        match self {
            Metric::L2 => v.max(0.0).sqrt(),
            Metric::L1 => v,
        }
    }

    /// Convert a metric-space distance to device space (for comparing
    /// against tile outputs without converting whole matrices).
    #[inline]
    pub fn to_device(&self, d: f32) -> f32 {
        match self {
            Metric::L2 => d * d,
            Metric::L1 => d,
        }
    }

    /// Device-space distance between two vectors, computed on the CPU
    /// with the same accumulation order the emulated tile uses (sum of
    /// squared differences for L2 — no sqrt — and sum of absolute
    /// differences for L1).  Lets a CPU path emit values bit-identical
    /// to what a device tile would have produced for the same pair.
    #[inline]
    pub fn device_dist(&self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        match self {
            Metric::L2 => {
                let mut s = 0.0f32;
                for k in 0..a.len() {
                    let d = a[k] - b[k];
                    s += d * d;
                }
                s
            }
            Metric::L1 => {
                let mut s = 0.0f32;
                for k in 0..a.len() {
                    s += (a[k] - b[k]).abs();
                }
                s
            }
        }
    }

    /// [`Metric::device_dist`] between matrix rows.
    #[inline]
    pub fn device_dist_rows(&self, a: &Matrix, i: usize, b: &Matrix, j: usize) -> f32 {
        self.device_dist(a.row(i), b.row(j))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l2_matches_dist2_sqrt() {
        let a = Matrix::from_vec(vec![0.0, 0.0, 3.0, 4.0], 2, 2).unwrap();
        assert_eq!(Metric::L2.dist_rows(&a, 0, &a, 1), 5.0);
        assert_eq!(Metric::L1.dist_rows(&a, 0, &a, 1), 7.0);
    }

    #[test]
    fn device_roundtrip() {
        for m in [Metric::L2, Metric::L1] {
            let d = 3.5f32;
            let back = m.from_device(m.to_device(d));
            assert!((back - d).abs() < 1e-6);
        }
    }

    #[test]
    fn triangle_inequality_holds_for_both() {
        let pts = Matrix::from_vec(
            vec![0.1, 0.9, -0.5, 0.3, 0.7, -0.2, 0.0, 0.4, -0.9, 0.6, 0.2, 0.8],
            4,
            3,
        )
        .unwrap();
        for m in [Metric::L2, Metric::L1] {
            for i in 0..4 {
                for j in 0..4 {
                    for k in 0..4 {
                        let dij = m.dist_rows(&pts, i, &pts, j);
                        let dik = m.dist_rows(&pts, i, &pts, k);
                        let dkj = m.dist_rows(&pts, k, &pts, j);
                        assert!(dij <= dik + dkj + 1e-5, "{m:?} TI violated");
                    }
                }
            }
        }
    }

    #[test]
    fn device_dist_matches_device_space_of_metric_dist() {
        let a = Matrix::from_vec(vec![0.0, 0.0, 3.0, 4.0], 2, 2).unwrap();
        assert_eq!(Metric::L2.device_dist_rows(&a, 0, &a, 1), 25.0);
        assert_eq!(Metric::L1.device_dist_rows(&a, 0, &a, 1), 7.0);
    }

    #[test]
    fn ddsl_parse() {
        assert_eq!(Metric::from_ddsl("L1"), Metric::L1);
        assert_eq!(Metric::from_ddsl("Unweighted L1"), Metric::L1);
        assert_eq!(Metric::from_ddsl("L2"), Metric::L2);
        assert_eq!(Metric::from_ddsl("Euclidean"), Metric::L2);
    }
}
