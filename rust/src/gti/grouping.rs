//! Data grouping: partition a point set into landmark-centered groups.
//!
//! Groups are the granularity of every GTI bound and of accelerator
//! dispatch.  Construction is Lloyd-style refinement on a *sample* (the
//! paper's `n_iteration` grouping iterations, §VI-A), followed by one
//! full assignment pass and radius computation.  Cost is
//! `O(sample * g * iters + n * g)` distance computations on the CPU —
//! the `Latency_filt` term of the paper's Eq. 6.

use crate::data::Matrix;
use crate::util::rng::Rng;
use crate::{Error, Result};

/// A grouping of `n` points into `g` landmark-centered groups.
#[derive(Debug, Clone)]
pub struct Grouping {
    /// Landmark (center) of each group, `(g, d)`.
    pub centers: Matrix,
    /// Radius of each group: max distance from a member to the landmark
    /// (the `d_max(a, A_ref)` of Eq. 2).
    pub radii: Vec<f32>,
    /// Group id of every point.
    pub assign: Vec<u32>,
    /// Member point ids per group (ascending within each group).
    pub members: Vec<Vec<u32>>,
    /// Number of distance computations spent building the grouping
    /// (reported as filter overhead in the benches).
    pub build_dist_comps: u64,
}

impl Grouping {
    /// Heuristic group count used when the config leaves it at 0:
    /// `sqrt(n)/2` clamped to [1, 4096] — keeps the group-pair bound
    /// matrix (z_src x z_trg) small per the paper's memory argument.
    pub fn auto_groups(n: usize) -> usize {
        (((n as f64).sqrt() / 2.0) as usize).clamp(1, 4096)
    }

    /// Build a grouping with `g` groups and `iters` refinement passes
    /// under the Euclidean metric (the common case; see
    /// [`Grouping::build_with_metric`] for L1).
    pub fn build(
        points: &Matrix,
        g: usize,
        iters: usize,
        sample: usize,
        seed: u64,
    ) -> Result<Grouping> {
        Self::build_with_metric(points, g, iters, sample, seed, super::Metric::L2)
    }

    /// Metric-aware build: radii are stored in *metric* units so the
    /// Eq. 2 bounds remain sound for any triangle-inequality metric.
    ///
    /// `sample` caps how many points the refinement sees; the final
    /// assignment pass always covers all points.
    pub fn build_with_metric(
        points: &Matrix,
        g: usize,
        iters: usize,
        sample: usize,
        seed: u64,
        metric: super::Metric,
    ) -> Result<Grouping> {
        let n = points.rows();
        let d = points.cols();
        if n == 0 {
            return Err(Error::Data("cannot group an empty point set".into()));
        }
        let g = g.min(n).max(1);
        let mut rng = Rng::new(seed ^ 0x6701);
        let mut dist_comps = 0u64;

        // Seed centers from a random sample of distinct points.
        let seed_idx = rng.sample_indices(n, g);
        let mut centers = points.gather_rows(&seed_idx);

        // Lloyd refinement on a sample.
        let sample_n = sample.clamp(g, n);
        let sample_idx = if sample_n >= n {
            (0..n).collect::<Vec<_>>()
        } else {
            rng.sample_indices(n, sample_n)
        };
        for _ in 0..iters {
            let mut sums = vec![0.0f64; g * d];
            let mut counts = vec![0u32; g];
            for &pi in &sample_idx {
                let (gi, _) = nearest_center(points, pi, &centers, metric);
                dist_comps += g as u64;
                counts[gi] += 1;
                let row = points.row(pi);
                for k in 0..d {
                    sums[gi * d + k] += row[k] as f64;
                }
            }
            for gi in 0..g {
                if counts[gi] > 0 {
                    let c = centers.row_mut(gi);
                    for k in 0..d {
                        c[k] = (sums[gi * d + k] / counts[gi] as f64) as f32;
                    }
                }
                // Empty groups keep their seed position; the full
                // assignment pass below may still populate them.
            }
        }

        // Full assignment + radii (radii in metric units).
        let mut assign = vec![0u32; n];
        let mut radii = vec![0.0f32; g];
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); g];
        for pi in 0..n {
            let (gi, r) = nearest_center(points, pi, &centers, metric);
            dist_comps += g as u64;
            assign[pi] = gi as u32;
            members[gi].push(pi as u32);
            if r > radii[gi] {
                radii[gi] = r;
            }
        }

        Ok(Grouping { centers, radii, assign, members, build_dist_comps: dist_comps })
    }

    pub fn num_groups(&self) -> usize {
        self.members.len()
    }

    pub fn num_points(&self) -> usize {
        self.assign.len()
    }

    /// Largest group size (determines tile batching shape).
    pub fn max_group_size(&self) -> usize {
        self.members.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Recompute the radius of every group from scratch (used after
    /// N-body position updates when membership is kept fixed).
    pub fn refresh_radii(&mut self, points: &Matrix) {
        for (gi, mem) in self.members.iter().enumerate() {
            let mut r = 0.0f32;
            for &pi in mem {
                let d2 = points.dist2(pi as usize, &self.centers, gi);
                if d2 > r {
                    r = d2;
                }
            }
            self.radii[gi] = r.sqrt();
        }
    }

    /// Move each group center to its members' centroid and return the
    /// per-group drift (distance moved) — the trace-based landmark
    /// update for N-body (Fig. 2d).
    pub fn recenter(&mut self, points: &Matrix) -> Vec<f32> {
        let d = points.cols();
        let mut drifts = vec![0.0f32; self.num_groups()];
        for (gi, mem) in self.members.iter().enumerate() {
            if mem.is_empty() {
                continue;
            }
            let mut centroid = vec![0.0f64; d];
            for &pi in mem {
                let row = points.row(pi as usize);
                for k in 0..d {
                    centroid[k] += row[k] as f64;
                }
            }
            let inv = 1.0 / mem.len() as f64;
            let mut drift2 = 0.0f32;
            let c = self.centers.row_mut(gi);
            for k in 0..d {
                let nc = (centroid[k] * inv) as f32;
                let delta = nc - c[k];
                drift2 += delta * delta;
                c[k] = nc;
            }
            drifts[gi] = drift2.sqrt();
        }
        self.refresh_radii(points);
        drifts
    }

    /// Validate internal invariants (used by property tests).
    pub fn check_invariants(&self, points: &Matrix) -> std::result::Result<(), String> {
        let n = points.rows();
        if self.assign.len() != n {
            return Err(format!("assign len {} != n {n}", self.assign.len()));
        }
        let total: usize = self.members.iter().map(Vec::len).sum();
        if total != n {
            return Err(format!("members cover {total} points, want {n}"));
        }
        for (gi, mem) in self.members.iter().enumerate() {
            for &pi in mem {
                if self.assign[pi as usize] as usize != gi {
                    return Err(format!("point {pi} in group {gi} but assigned elsewhere"));
                }
                let dist = points.dist2(pi as usize, &self.centers, gi).sqrt();
                if dist > self.radii[gi] * (1.0 + 1e-4) + 1e-5 {
                    return Err(format!(
                        "point {pi} at {dist} outside group {gi} radius {}",
                        self.radii[gi]
                    ));
                }
            }
        }
        Ok(())
    }
}

/// Content fingerprint of a point set: FNV-1a over the shape and every
/// f32 bit pattern.  Two matrices fingerprint equal iff they are
/// bit-identical, which is what lets the serving layer's
/// [`crate::serve::GroupingCache`] key groupings by *data* rather than
/// by pointer: a grouping built for one fingerprint is byte-for-byte
/// the grouping that `build_with_metric` would produce again for the
/// same parameters (the build is deterministic), so cache reuse can
/// never change results.
pub fn fingerprint(points: &Matrix) -> u64 {
    fingerprint_pair(points).0
}

/// Primary fingerprint plus an independent secondary probe, computed in
/// ONE pass over the data (hashing is the per-lookup cost of the
/// serving cache's hot path, so the two walks are fused).  The primary
/// is FNV-1a; the probe is FNV-1 (multiply-before-xor) from a different
/// offset basis with the shape folded in rotated, so a simultaneous
/// collision of both 64-bit values requires ~2^128 luck.
pub fn fingerprint_pair(points: &Matrix) -> (u64, u64) {
    const PRIME: u64 = 0x0000_0100_0000_01B3;
    fn eat(a: &mut u64, b: &mut u64, word: u64, bytes: u32) {
        let mut w = word;
        for _ in 0..bytes {
            let byte = w & 0xFF;
            *a = (*a ^ byte).wrapping_mul(PRIME); // FNV-1a
            *b = b.wrapping_mul(PRIME) ^ byte; // FNV-1
            w >>= 8;
        }
    }
    let mut a: u64 = 0xCBF2_9CE4_8422_2325;
    let mut b: u64 = 0x6C62_272E_07BB_0142
        ^ (points.rows() as u64).rotate_left(17)
        ^ (points.cols() as u64).rotate_left(43);
    eat(&mut a, &mut b, points.rows() as u64, 8);
    eat(&mut a, &mut b, points.cols() as u64, 8);
    for &v in points.as_slice() {
        eat(&mut a, &mut b, v.to_bits() as u64, 4);
    }
    (a, b)
}

/// Nearest center under `metric`; returns (group, metric distance).
/// The L2 path scans squared distances (cheaper) and converts once.
#[inline]
fn nearest_center(
    points: &Matrix,
    pi: usize,
    centers: &Matrix,
    metric: super::Metric,
) -> (usize, f32) {
    match metric {
        super::Metric::L2 => {
            let mut best = (0usize, f32::INFINITY);
            for gi in 0..centers.rows() {
                let d2 = points.dist2(pi, centers, gi);
                if d2 < best.1 {
                    best = (gi, d2);
                }
            }
            (best.0, best.1.max(0.0).sqrt())
        }
        m => {
            let mut best = (0usize, f32::INFINITY);
            for gi in 0..centers.rows() {
                let d = m.dist(points.row(pi), centers.row(gi));
                if d < best.1 {
                    best = (gi, d);
                }
            }
            best
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::prop;

    #[test]
    fn grouping_covers_all_points() {
        let ds = synthetic::clustered(500, 6, 8, 0.05, 1);
        let g = Grouping::build(&ds.points, 16, 3, 256, 7).unwrap();
        g.check_invariants(&ds.points).unwrap();
        assert_eq!(g.num_groups(), 16);
    }

    #[test]
    fn more_groups_shrink_radii() {
        let ds = synthetic::uniform(800, 4, 2);
        let g4 = Grouping::build(&ds.points, 4, 3, 800, 7).unwrap();
        let g64 = Grouping::build(&ds.points, 64, 3, 800, 7).unwrap();
        let mean = |g: &Grouping| g.radii.iter().sum::<f32>() / g.radii.len() as f32;
        assert!(mean(&g64) < mean(&g4));
    }

    #[test]
    fn single_group_radius_covers_extent() {
        let ds = synthetic::uniform(100, 3, 3);
        let g = Grouping::build(&ds.points, 1, 2, 100, 7).unwrap();
        g.check_invariants(&ds.points).unwrap();
        assert_eq!(g.members[0].len(), 100);
    }

    #[test]
    fn recenter_reports_drift_and_keeps_invariants() {
        let ds = synthetic::clustered(300, 3, 5, 0.02, 4);
        let mut pts = ds.points.clone();
        let mut g = Grouping::build(&pts, 8, 2, 300, 9).unwrap();
        // Shift all points; recenter should follow and report drift.
        for i in 0..pts.rows() {
            for v in pts.row_mut(i) {
                *v += 0.5;
            }
        }
        let drifts = g.recenter(&pts);
        assert!(drifts.iter().any(|&d| d > 0.4));
        g.check_invariants(&pts).unwrap();
    }

    #[test]
    fn fingerprint_is_content_addressed() {
        let a = synthetic::clustered(200, 5, 4, 0.05, 9);
        let b = synthetic::clustered(200, 5, 4, 0.05, 9);
        let c = synthetic::clustered(200, 5, 4, 0.05, 10);
        assert_eq!(super::fingerprint(&a.points), super::fingerprint(&b.points));
        assert_ne!(super::fingerprint(&a.points), super::fingerprint(&c.points));
        // Shape participates: same bits, different shape must differ.
        let flat = Matrix::from_vec(a.points.as_slice().to_vec(), 1000, 1).unwrap();
        assert_ne!(super::fingerprint(&a.points), super::fingerprint(&flat));
        // A single-value change shows up in the fingerprint.
        let mut d = a.points.clone();
        d.row_mut(57)[2] += 0.25;
        assert_ne!(super::fingerprint(&a.points), super::fingerprint(&d));
    }

    #[test]
    fn prop_grouping_invariants_hold() {
        prop::check(
            &prop::Config { cases: 12, max_size: 300, ..Default::default() },
            |rng, size| {
                let n = size.max(4);
                let d = 1 + rng.below(8);
                let g = 1 + rng.below(n.min(20));
                let pts = Matrix::from_vec(prop::gen_points(rng, n, d, 5.0), n, d).unwrap();
                (pts, g)
            },
            |(pts, g)| {
                let grouping = Grouping::build(pts, *g, 2, 128, 3).map_err(|e| e.to_string())?;
                grouping.check_invariants(pts)
            },
        );
    }
}
