//! Generalized Triangle Inequality (GTI) optimization — paper §IV.
//!
//! The three GTI ingredients map to submodules:
//!
//! * [`grouping`] — data grouping: points are partitioned into groups,
//!   each with a landmark (center) and radius; groups are the unit of
//!   bound computation and of accelerator dispatch (**Group-level
//!   bound computation**, Fig. 2e/2f).
//! * [`bounds`] — the bound algebra: one-landmark (Fig. 2a),
//!   two-landmark (Fig. 2b, Eq. 1), group-level (Eq. 2) and
//!   trace-based drift bounds (Fig. 2c/2d, Eq. 3).
//! * [`filter`] — per-algorithm candidate filters built from those
//!   bounds: which (source group x target group) pairs survive and
//!   must go to the accelerator.
//!
//! Everything here runs on the **CPU** side of the heterogeneous
//! design: complex, branchy, dependency-laden — exactly the work the
//! paper assigns to the host (§V intro).

pub mod bounds;
pub mod filter;
pub mod grouping;
pub mod metric;

pub use bounds::{
    center_group_drift, group_pair_bounds, widen_pair_lbs, widen_point_bounds, DriftWidening,
    GroupPairBound,
};
pub use filter::{unstable_members, FilterStats, KmeansFilter, KnnFilter, NbodyFilter};
pub use grouping::{fingerprint, fingerprint_pair, Grouping};
pub use metric::Metric;
