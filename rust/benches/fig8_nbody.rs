//! Fig. 8c — N-body simulation performance comparison across the
//! Table V particle counts (Baseline / TOP / AccD), normalized
//! speedups.  The CBLAS column is absent as in the paper's setup the
//! matrix decomposition does not apply to the radius-masked force
//! kernel.

use accd::data::tablev;
use accd::figures;
use accd::util::bench::{fmt_x, Table};
use accd::util::geomean;

fn main() {
    let scale = figures::bench_scale();
    let specs = tablev::nbody_datasets();
    eprintln!("fig8c: N-body sweep at scale {scale} ({} datasets)", specs.len());
    let rows = match figures::fig8_nbody(scale, &specs) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("fig8c failed (run `make artifacts`?): {e}");
            std::process::exit(1);
        }
    };
    let speedups = figures::speedups(&rows);
    let modeled = figures::modeled_speedups(&rows);
    let mut table = Table::new(&["dataset", "TOP", "AccD (measured)", "AccD (DE10 model)"]);
    let mut per_impl: std::collections::HashMap<&str, Vec<f64>> = Default::default();
    for spec in &specs {
        let get = |set: &[(String, String, f64)], imp: &str| {
            set.iter()
                .find(|(d, i, _)| d == spec.name && i == imp)
                .map(|(_, _, s)| *s)
                .unwrap_or(f64::NAN)
        };
        let (t, a) = (get(&speedups, "top"), get(&speedups, "accd"));
        let am = get(&modeled, "accd");
        per_impl.entry("top").or_default().push(t);
        per_impl.entry("accd").or_default().push(a);
        per_impl.entry("accd_model").or_default().push(am);
        table.row(vec![spec.name.to_string(), fmt_x(t), fmt_x(a), fmt_x(am)]);
    }
    table.row(vec![
        "geomean".to_string(),
        fmt_x(geomean(&per_impl["top"])),
        fmt_x(geomean(&per_impl["accd"])),
        fmt_x(geomean(&per_impl["accd_model"])),
    ]);
    table.print(&format!("Fig. 8c: N-body speedup vs Baseline (scale {scale})"));
}
