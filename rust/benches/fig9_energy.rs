//! Fig. 9 — energy-efficiency comparison (TOP / CBLAS / AccD vs
//! Baseline) for all three algorithm families, using the calibrated
//! power model (fpga::power) on the measured run times.
//!
//! Paper headline: AccD averages 99.63x better energy efficiency, with
//! 116.85x on K-means.

use accd::data::tablev;
use accd::figures;
use accd::util::bench::{fmt_x, Table};
use accd::util::geomean;

fn print_family(
    title: &str,
    specs: &[accd::data::DatasetSpec],
    rows: &[figures::FigRow],
    impls: &[&str],
) {
    let effs = figures::energy_effs(rows);
    let modeled = figures::modeled_energy_effs(rows);
    let mut headers = vec!["dataset"];
    headers.extend_from_slice(impls);
    headers.push("accd (DE10 model)");
    let mut table = Table::new(&headers);
    let mut per_impl: std::collections::HashMap<&str, Vec<f64>> = Default::default();
    for spec in specs {
        let mut cells = vec![spec.name.to_string()];
        for &imp in impls {
            let e = effs
                .iter()
                .find(|(d, i, _)| d == spec.name && i == imp)
                .map(|(_, _, s)| *s)
                .unwrap_or(f64::NAN);
            per_impl.entry(imp).or_default().push(e);
            cells.push(fmt_x(e));
        }
        let em = modeled
            .iter()
            .find(|(d, i, _)| d == spec.name && i == "accd")
            .map(|(_, _, s)| *s)
            .unwrap_or(f64::NAN);
        per_impl.entry("accd_model").or_default().push(em);
        cells.push(fmt_x(em));
        table.row(cells);
    }
    let mut geo = vec!["geomean".to_string()];
    for &imp in impls {
        geo.push(fmt_x(geomean(&per_impl[imp])));
    }
    geo.push(fmt_x(geomean(&per_impl["accd_model"])));
    table.row(geo);
    table.print(title);
}

fn main() {
    let scale = figures::bench_scale();
    eprintln!("fig9: energy sweep at scale {scale}");
    let km_specs = tablev::kmeans_datasets();
    let knn_specs = tablev::knn_datasets();
    let nb_specs = tablev::nbody_datasets();
    let run = || -> accd::Result<()> {
        let km = figures::fig8_kmeans(scale, &km_specs)?;
        print_family(
            &format!("Fig. 9a: K-means energy efficiency vs Baseline (scale {scale}; paper avg 116.85x for AccD)"),
            &km_specs,
            &km,
            &["top", "cblas", "accd"],
        );
        let knn = figures::fig8_knn(scale, &knn_specs)?;
        print_family(
            &format!("Fig. 9b: KNN-join energy efficiency vs Baseline (scale {scale})"),
            &knn_specs,
            &knn,
            &["top", "cblas", "accd"],
        );
        let nb = figures::fig8_nbody(scale, &nb_specs)?;
        print_family(
            &format!("Fig. 9c: N-body energy efficiency vs Baseline (scale {scale})"),
            &nb_specs,
            &nb,
            &["top", "accd"],
        );
        Ok(())
    };
    if let Err(e) = run() {
        eprintln!("fig9 failed (run `make artifacts`?): {e}");
        std::process::exit(1);
    }
}
