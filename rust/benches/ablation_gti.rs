//! Ablation bench: the design choices DESIGN.md calls out, isolated.
//!
//! 1. **Group count sweep** (paper Eq. 7 trade-off): filter saving vs
//!    bound-computation overhead as z varies around the auto heuristic.
//! 2. **Layout on/off** (paper §V-A): inter-group scheduling's slab
//!    reuse vs natural order on the same candidate sets.
//! 3. **Tile mixing on/off** (perf pass): large-variant mixed tiling vs
//!    base-tile-only execution of identical distance jobs.
//! 4. **Trace-based reuse on/off** (paper Fig. 2d): N-body filter with
//!    drift-widened cached center distances vs per-step recomputation.

use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::synthetic;
use accd::fpga::TileJob;
use accd::gti::{Grouping, KnnFilter, NbodyFilter};
use accd::layout;
use accd::util::bench::{fmt_x, Table};

fn main() {
    group_count_sweep();
    layout_onoff();
    tile_mixing();
    trace_reuse();
}

/// Eq. 7 trade-off: more groups prune more pairs but cost more bounds.
fn group_count_sweep() {
    let src = synthetic::clustered(4_000, 8, 40, 0.02, 1);
    let trg = synthetic::clustered(8_000, 8, 40, 0.02, 2);
    let k = 50;
    let mut table = Table::new(&["z (groups)", "saving", "bound comps", "group pairs kept"]);
    for z in [8usize, 16, 32, 64, 128, 256] {
        let gs = Grouping::build(&src.points, z, 3, 4096, 3).unwrap();
        let gt = Grouping::build(&trg.points, z, 3, 4096, 4).unwrap();
        let mut f = KnnFilter::new();
        let (_c, _b) = f.candidates(&gs, &gt, k);
        table.row(vec![
            z.to_string(),
            format!("{:.1}%", 100.0 * f.stats.saving_ratio()),
            f.stats.bound_comps.to_string(),
            format!("{}/{}", f.stats.surviving_group_pairs, f.stats.group_pairs),
        ]);
    }
    table.print("Ablation 1: KNN group-count sweep (Eq. 7 trade-off; 4k x 8k, K=50)");
}

/// Fig. 4b scheduling: reuse ratio scheduled vs natural order.
fn layout_onoff() {
    let src = synthetic::clustered(4_000, 8, 40, 0.02, 5);
    let trg = synthetic::clustered(8_000, 8, 40, 0.02, 6);
    let gs = Grouping::build(&src.points, 64, 3, 4096, 7).unwrap();
    let gt = Grouping::build(&trg.points, 64, 3, 4096, 8).unwrap();
    let mut f = KnnFilter::new();
    let (cands, _) = f.candidates(&gs, &gt, 50);
    let natural: Vec<u32> = (0..cands.len() as u32).collect();
    let nat = layout::measure_reuse(&natural, &cands);
    let order = layout::schedule_source_groups(&cands);
    let sch = layout::measure_reuse(&order, &cands);
    let mut table = Table::new(&["order", "fetches", "reused", "reuse ratio"]);
    for (name, s) in [("natural", &nat), ("scheduled (Fig. 4b)", &sch)] {
        table.row(vec![
            name.to_string(),
            s.fetches.to_string(),
            s.reused.to_string(),
            format!("{:.1}%", 100.0 * s.reuse_ratio()),
        ]);
    }
    table.print("Ablation 2: inter-group schedule on/off (target-slab temporal reuse)");
}

/// Perf-pass tiling: identical distance jobs with and without the
/// large-tile variants (base-only forced via a 64-only manifest view
/// is not constructible here, so we compare against per-64-row jobs).
fn tile_mixing() {
    let Ok(engine) = Engine::new(AccdConfig::new()) else {
        eprintln!("skipping tile ablation (no artifacts)");
        return;
    };
    let d = 16usize;
    let rows = 2048usize;
    let cols = 2048usize;
    let src = synthetic::uniform(rows, d, 9);
    let trg = synthetic::uniform(cols, d, 10);
    let d_pad = engine.runtime.manifest().tile.pad_d(d).unwrap();
    let mk_job = |r0: usize, r1: usize| -> TileJob {
        let ids: Vec<u32> = (r0 as u32..r1 as u32).collect();
        let rows_pad = accd::util::round_up(ids.len(), 64);
        TileJob {
            src: accd::fpga::FpgaDevice::pad_rows(&src.points, &ids, rows_pad, d_pad),
            src_rows: ids.len(),
            trg: std::sync::Arc::new(src_trg_slab(&trg.points, cols, d, d_pad)),
            trg_rows: cols,
            d,
            d_padded: d_pad,
            metric: "l2sq",
        }
    };
    // Warm both executable variants, then measure.
    let _ = engine.device.distance_block(&mk_job(0, rows)).unwrap();
    std::env::set_var("ACCD_FORCE_BASE_TILES", "1");
    let _ = engine.device.distance_block(&mk_job(0, 64)).unwrap();
    std::env::remove_var("ACCD_FORCE_BASE_TILES");
    // Mixed tiling: device segments the long axis with 512 variants.
    engine.device.reset_stats();
    let t = std::time::Instant::now();
    let _ = engine.device.distance_block(&mk_job(0, rows)).unwrap();
    let mixed = t.elapsed().as_secs_f64();
    let mixed_tiles = engine.device.stats().tiles;
    // Base-only: ACCD_FORCE_BASE_TILES pins every dispatch to 64x64.
    std::env::set_var("ACCD_FORCE_BASE_TILES", "1");
    engine.device.reset_stats();
    let t = std::time::Instant::now();
    let _ = engine.device.distance_block(&mk_job(0, rows)).unwrap();
    let base = t.elapsed().as_secs_f64();
    let base_tiles = engine.device.stats().tiles;
    std::env::remove_var("ACCD_FORCE_BASE_TILES");
    let mut table = Table::new(&["tiling", "wall (s)", "dispatches", "speedup"]);
    table.row(vec![
        "base 64x64 only".into(),
        format!("{base:.3}"),
        base_tiles.to_string(),
        fmt_x(1.0),
    ]);
    table.row(vec![
        "mixed 512/64 (perf pass)".into(),
        format!("{mixed:.3}"),
        mixed_tiles.to_string(),
        fmt_x(base / mixed),
    ]);
    table.print("Ablation 3: tile mixing on a 2048x2048x16 distance job");
}

fn src_trg_slab(m: &accd::data::Matrix, rows: usize, d: usize, d_pad: usize) -> Vec<f32> {
    let cols_pad = accd::util::round_up(rows, 64);
    let mut out = vec![0.0f32; cols_pad * d_pad];
    for r in 0..rows {
        out[r * d_pad..r * d_pad + d].copy_from_slice(m.row(r));
    }
    out
}

/// Trace-based reuse: bound computations with drift widening vs full
/// per-step recomputation of center distances.
fn trace_reuse() {
    let ds = synthetic::uniform(6_000, 3, 11);
    let z = 80;
    let r = 0.08f32;
    let steps = 12;
    // With trace reuse (refresh only when drift > 0.25 * r).
    let mut pts = ds.points.clone();
    let mut g = Grouping::build(&pts, z, 3, 4096, 12).unwrap();
    let mut f = NbodyFilter::new(&g, 0.25);
    let mut rng = accd::util::rng::Rng::new(13);
    for _ in 0..steps {
        for i in 0..pts.rows() {
            for v in pts.row_mut(i) {
                *v += rng.range_f32(-0.002, 0.002);
            }
        }
        let drifts = g.recenter(&pts);
        f.step(&g, &drifts, r);
        let _ = f.candidates(&g, r);
    }
    let with_trace = f.stats.bound_comps;
    let refreshes = f.refreshes;
    // Without: force refresh every step (refresh_frac = 0).
    let mut pts = ds.points.clone();
    let mut g = Grouping::build(&pts, z, 3, 4096, 12).unwrap();
    let mut f0 = NbodyFilter::new(&g, 0.0);
    let mut rng = accd::util::rng::Rng::new(13);
    for _ in 0..steps {
        for i in 0..pts.rows() {
            for v in pts.row_mut(i) {
                *v += rng.range_f32(-0.002, 0.002);
            }
        }
        let drifts = g.recenter(&pts);
        f0.step(&g, &drifts, r);
        let _ = f0.candidates(&g, r);
    }
    let without = f0.stats.bound_comps;
    let mut table = Table::new(&["mode", "bound comps", "center refreshes", "saving"]);
    table.row(vec![
        "recompute every step".into(),
        without.to_string(),
        f0.refreshes.to_string(),
        fmt_x(1.0),
    ]);
    table.row(vec![
        "trace-based (Fig. 2d)".into(),
        with_trace.to_string(),
        refreshes.to_string(),
        fmt_x(without as f64 / with_trace as f64),
    ]);
    table.print(&format!(
        "Ablation 4: trace-based bound reuse over {steps} N-body steps (6k particles, z={z})"
    ));
}
