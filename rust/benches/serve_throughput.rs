//! Serving-throughput bench: N coalescible queries through
//! `serve::QueryBatcher` vs the same N queries as independent `Engine`
//! calls — swept across engine-shard counts (1/2/4), plus a
//! repeated-flush scenario that shows the persistent per-shard slab
//! cache converting packing work into cache hits, plus a
//! repeated-cohort K-means scenario that shows the lockstep scheduler
//! sharing packed assignment tiles across same-dataset programs AND
//! the incremental TI bounds pruning device work from iteration 2 on
//! (the row carries a `prune_rate`; the smoke run FAILS if later
//! iterations prune nothing), plus a deduplicated range-join cohort
//! (radius queries whose sources share the target's cluster centers,
//! so the group-level lower bounds prove most group pairs outside the
//! threshold; the row carries a group-pair `prune_rate` and the smoke
//! run FAILS if the bounds prune nothing or no within-threshold pair
//! is ever emitted), plus
//! a deadline/latency scenario (EDF-LPT placement, staggered generous
//! deadlines) that emits p50/p95/p99 latency + deadline met/miss
//! counts and FAILS the smoke run if the deadline-aware planner
//! misses a deadline despite sufficient capacity, plus two open-loop
//! arrival-trace scenarios (seeded Poisson and bursty) driven through
//! the always-on `serve::Server` on a `VirtualClock` — producers
//! submit on the arrival schedule without waiting for responses, the
//! scheduler thread wakes on the registered clock waker, and the rows
//! record q/s, latency percentiles and shed/backpressure counters,
//! plus three emulated multi-device scenarios: a 2-device/2-shard
//! cold flush whose second cohort per shard streams its slab upload
//! under the first cohort's compute (FAILS if the double-buffered
//! overlap accounting records nothing), a warmth A/B that runs the
//! same repeating two-cohort workload under blind LPT and under
//! movement-aware LPT on devices too small to hold both working sets
//! (FAILS if the movement-aware planner is slower), and a sustained
//! overload burst against a tiny `queue_cap` under the `reject`
//! policy (FAILS if nothing is shed — the backpressure path
//! regressed), plus two calibration scenarios: the repeated-flush row
//! gates the self-calibrating cost-to-time model (FAILS if the final
//! warm flush's predicted-vs-actual error p95 exceeds 500‰), and a
//! saturated diurnal arrival trace runs twice — reactive vs
//! `predictive_shed` — and FAILS unless the predictive run sheds the
//! already-doomed peak-tail queries and finishes with strictly fewer
//! deadline misses than the reactive baseline, plus a purely modeled
//! `serve.devices` × `serve.dma_gbps` frontier row ranking device
//! counts and link speeds through the Eq. 5 multi-device latency
//! model.
//!
//! The batched path amortizes exactly what a serving deployment
//! amortizes: the target grouping is built once per cohort instead of
//! once per query, packed slabs are shared across queries (and across
//! flushes, until LRU-evicted over the byte budget), duplicated
//! queries are answered from one execution, independent cohorts run
//! concurrently on the engine pool, and idle shards steal
//! not-yet-started units when the cost estimates misfire.
//! `ServeStats` reports the sharing that proves it happened.
//!
//! Machine-readable output: every scenario row is also written to
//! `BENCH_serve.json` (override the path with `ACCD_BENCH_JSON`) —
//! q/s, lockstep shared-tile hit rate and steal count per scenario —
//! so CI can archive the numbers as an artifact.
//!
//! Scale down with ACCD_BENCH_FAST=1 (CI smoke mode).

use std::sync::Arc;
use std::time::{Duration, Instant};

use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::{synthetic, Dataset};
use accd::dse::{DesignConfig, Explorer, Workload as DseWorkload};
use accd::metrics::ServeStats;
use accd::serve::{QueryBatcher, ServeRequest, Server, VirtualClock};
use accd::util::bench::{fmt_x, Table};
use accd::util::json::{self, Value};
use accd::util::rng::Rng;

/// One scenario's machine-readable record.  Takes the merged stats
/// view directly so both the caller-driven `QueryBatcher` scenarios
/// and the always-on `Server` scenarios (whose batcher lives on the
/// scheduler thread) emit identical rows.
fn scenario_row(
    name: &str,
    queries: usize,
    wall_secs: f64,
    speedup: f64,
    stats: &ServeStats,
    shards: usize,
) -> Value {
    let slab_total = stats.slab_cache_hits + stats.slab_cache_misses;
    let shared_tile_rate = if slab_total == 0 {
        0.0
    } else {
        stats.lockstep_shared_tiles as f64 / slab_total as f64
    };
    let (lat_p50, lat_p95, lat_p99) = stats.latency_percentiles_ms();
    json::obj(vec![
        ("name", json::s(name.to_string())),
        ("queries", json::num(queries as f64)),
        ("wall_secs", json::num(wall_secs)),
        ("qps", json::num(queries as f64 / wall_secs.max(1e-12))),
        ("speedup_vs_sequential", json::num(speedup)),
        ("shards", json::num(shards as f64)),
        ("tiles_shared_ratio", json::num(stats.tiles_shared_ratio())),
        ("slab_hit_rate", json::num(stats.slab_hit_rate())),
        ("lockstep_rounds", json::num(stats.lockstep_rounds as f64)),
        ("lockstep_shared_tiles", json::num(stats.lockstep_shared_tiles as f64)),
        ("lockstep_shared_tile_rate", json::num(shared_tile_rate)),
        ("steals", json::num(stats.steals as f64)),
        ("transfer_ns", json::num(stats.transfer_ns as f64)),
        ("compute_ns", json::num(stats.compute_ns as f64)),
        ("overlap_ns", json::num(stats.overlap_ns as f64)),
        ("latency_p50_ms", json::num(lat_p50)),
        ("latency_p95_ms", json::num(lat_p95)),
        ("latency_p99_ms", json::num(lat_p99)),
        ("deadline_met", json::num(stats.deadline_met as f64)),
        ("deadline_misses", json::num(stats.deadline_misses as f64)),
        ("shed", json::num(stats.shed as f64)),
        ("predicted_sheds", json::num(stats.predicted_sheds as f64)),
        ("predict_err_p50_permille", json::num(stats.predict_err_p50_permille() as f64)),
        ("predict_err_p95_permille", json::num(stats.predict_err_p95_permille() as f64)),
        ("queue_depth_watermark", json::num(stats.queue_depth_watermark as f64)),
        ("flush_failures", json::num(stats.flush_failures as f64)),
        ("tiles_skipped", json::num(stats.tiles_skipped as f64)),
        ("points_pruned", json::num(stats.points_pruned as f64)),
        ("bound_recomputes", json::num(stats.bound_recomputes as f64)),
    ])
}

/// Nearest-rank p95 over one flush's raw permille error samples (the
/// `ServeStats` accessors cover the whole run; the calibration gate
/// judges only the final, warmed-up flush).
fn p95_permille(samples: &[u64]) -> u64 {
    if samples.is_empty() {
        return 0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_unstable();
    sorted[(sorted.len() * 95).div_ceil(100) - 1]
}

fn main() {
    let fast = std::env::var("ACCD_BENCH_FAST").as_deref() == Ok("1");
    let (n_trg, n_src) = if fast { (4_000, 300) } else { (20_000, 1_500) };
    let k = 10;
    let mut scenarios: Vec<Value> = Vec::new();

    // Two hot target datasets, 6 distinct user queries, each submitted
    // twice (live traffic repeats itself) -> 12 coalescible queries in
    // two independent cohorts (so a second shard has work to steal).
    let trg_a = Arc::new(synthetic::clustered(n_trg, 8, 50, 0.02, 1));
    let trg_b = Arc::new(synthetic::clustered(n_trg / 2, 8, 30, 0.02, 2));
    let srcs: Vec<Arc<Dataset>> = (0..6)
        .map(|i| Arc::new(synthetic::clustered(n_src, 8, 10, 0.03, 100 + i as u64)))
        .collect();
    let queries: Vec<(Arc<Dataset>, Arc<Dataset>)> = (0..12)
        .map(|i| (srcs[i % 6].clone(), if i % 2 == 0 { trg_a.clone() } else { trg_b.clone() }))
        .collect();
    eprintln!(
        "serve_throughput: {} KNN queries (6 unique sources, 2 cohorts) x k={k} \
         against {}/{}-point targets",
        queries.len(),
        n_trg,
        n_trg / 2
    );

    let cfg = AccdConfig::new();
    let q = queries.len() as f64;

    // --- Sequential: one Engine call per query --------------------------
    let mut engine = Engine::new(cfg.clone()).expect("engine");
    let t = Instant::now();
    let mut seq_results = Vec::new();
    for (src, trg) in &queries {
        seq_results.push(engine.knn_join(src, trg, k).expect("solo knn"));
    }
    let seq_secs = t.elapsed().as_secs_f64();

    // --- Shard sweep: one flush through 1/2/4-shard pools ----------------
    let mut table = Table::new(&["path", "wall (s)", "q/s", "speedup"]);
    table.row(vec![
        "sequential Engine calls".into(),
        format!("{seq_secs:.3}"),
        format!("{:.1}", q / seq_secs),
        fmt_x(1.0),
    ]);
    let mut any_shared = false;
    for shards in [1usize, 2, 4] {
        let mut serve_cfg = cfg.serve.clone();
        serve_cfg.shards = shards;
        let mut batcher =
            QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), serve_cfg);
        for (src, trg) in &queries {
            batcher.submit(ServeRequest::knn(src.clone(), trg.clone(), k));
        }
        let t = Instant::now();
        let batched = batcher.flush().expect("flush");
        let secs = t.elapsed().as_secs_f64();

        // Parity spot-check: never report a win on wrong answers.
        for (i, (_, resp)) in batched.iter().enumerate() {
            let got = resp.as_knn().expect("knn response");
            assert_eq!(
                got.neighbors, seq_results[i].neighbors,
                "batched result diverged from sequential on query {i} ({shards} shards)"
            );
        }
        any_shared |= batcher.stats().tiles_shared > 0;
        table.row(vec![
            format!("serve, {shards} shard(s), cold"),
            format!("{secs:.3}"),
            format!("{:.1}", q / secs),
            fmt_x(seq_secs / secs),
        ]);
        scenarios.push(scenario_row(
            &format!("knn_cold_{shards}shard"),
            queries.len(),
            secs,
            seq_secs / secs,
            batcher.stats(),
            batcher.shard_count(),
        ));
    }
    table.print("Batched serving vs sequential engine calls (shard sweep)");

    // --- Repeated flushes: the persistent slab cache at work -------------
    let rounds = if fast { 3 } else { 5 };
    let mut serve_cfg = cfg.serve.clone();
    serve_cfg.shards = 2;
    let mut batcher = QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), serve_cfg);
    let mut round_rows = Table::new(&["flush", "wall (s)", "q/s", "slab hit rate"]);
    let mut warm_secs = 0.0f64;
    let mut final_err0 = 0usize;
    for round in 0..rounds {
        for (src, trg) in &queries {
            batcher.submit(ServeRequest::knn(src.clone(), trg.clone(), k));
        }
        if round + 1 == rounds {
            final_err0 = batcher.stats().predict_err_permille.len();
        }
        let hits0 = batcher.stats().slab_cache_hits;
        let misses0 = batcher.stats().slab_cache_misses;
        let t = Instant::now();
        batcher.flush().expect("repeated flush");
        let secs = t.elapsed().as_secs_f64();
        warm_secs += secs;
        let (hits, misses) = (
            batcher.stats().slab_cache_hits - hits0,
            batcher.stats().slab_cache_misses - misses0,
        );
        let rate = if hits + misses == 0 { 0.0 } else { hits as f64 / (hits + misses) as f64 };
        round_rows.row(vec![
            format!("{}", round + 1),
            format!("{secs:.3}"),
            format!("{:.1}", q / secs),
            format!("{:.1}%", 100.0 * rate),
        ]);
    }
    round_rows.print("Repeated flushes (2 shards): persistent slab cache");
    let stats = batcher.stats();
    println!("\n{}", stats.summary());
    scenarios.push(scenario_row(
        "knn_repeated_flushes_2shard",
        queries.len() * rounds,
        warm_secs,
        (seq_secs * rounds as f64) / warm_secs.max(1e-12),
        batcher.stats(),
        batcher.shard_count(),
    ));

    if !any_shared || stats.tiles_shared == 0 {
        eprintln!("FAIL: coalescible queries shared no tiles — coalescing regressed");
        std::process::exit(1);
    }
    if stats.slab_cache_hits == 0 {
        eprintln!("FAIL: repeated flushes hit no cached slabs — persistence regressed");
        std::process::exit(1);
    }
    // Calibration gate: by the final flush the cost-to-time model has
    // observed every cohort on its home shard at least twice, so its
    // service-time predictions must land within 50% (500‰) of the
    // observed modeled time at p95 — the self-calibrating model
    // earning its keep on a steady workload.
    let final_errs = &stats.predict_err_permille[final_err0..];
    let final_p95 = p95_permille(final_errs);
    println!(
        "calibration: final-flush predict error p95 {final_p95}\u{2030} \
         over {} unit(s) ({} predicted sheds)",
        final_errs.len(),
        stats.predicted_sheds,
    );
    if final_errs.is_empty() || final_p95 > 500 {
        eprintln!(
            "FAIL: calibrated service-time predictions off by {final_p95}\u{2030} (p95) on \
             the final warm flush across {} unit(s) (limit 500\u{2030}) — the cost \
             calibrator regressed",
            final_errs.len()
        );
        std::process::exit(1);
    }

    // --- Repeated-cohort K-means: lockstep shared assignment tiles -------
    // Six same-dataset K-means queries with different k: NOT
    // deduplicable, so six distinct iterative programs co-reside under
    // the lockstep scheduler and share one packed assignment slab (and
    // one grouping) through the shard caches.
    let (n_km, km_iters) = if fast { (3_000, 4) } else { (12_000, 8) };
    let km_ds = Arc::new(synthetic::clustered(n_km, 8, 16, 0.03, 7));
    let km_ks = [8usize, 12, 16, 20, 24, 32];

    let mut engine = Engine::new(cfg.clone()).expect("engine");
    let t = Instant::now();
    let mut km_seq = Vec::new();
    for &kk in &km_ks {
        km_seq.push(engine.kmeans(&km_ds, kk, km_iters).expect("solo kmeans"));
    }
    let km_seq_secs = t.elapsed().as_secs_f64();

    let mut serve_cfg = cfg.serve.clone();
    serve_cfg.shards = 2;
    let mut km_batcher =
        QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), serve_cfg);
    for &kk in &km_ks {
        km_batcher.submit(ServeRequest::kmeans(km_ds.clone(), kk, km_iters));
    }
    let t = Instant::now();
    let km_out = km_batcher.flush().expect("kmeans flush");
    let km_secs = t.elapsed().as_secs_f64();
    for (i, (_, resp)) in km_out.iter().enumerate() {
        let got = resp.as_kmeans().expect("kmeans response");
        assert_eq!(got.assign, km_seq[i].assign, "lockstep kmeans diverged on query {i}");
        assert_eq!(got.sse, km_seq[i].sse, "lockstep kmeans sse diverged on query {i}");
    }
    let km_stats = km_batcher.stats();
    let mut km_table = Table::new(&["path", "wall (s)", "q/s", "speedup"]);
    km_table.row(vec![
        "sequential kmeans calls".into(),
        format!("{km_seq_secs:.3}"),
        format!("{:.1}", km_ks.len() as f64 / km_seq_secs),
        fmt_x(1.0),
    ]);
    km_table.row(vec![
        "serve, 2 shards, lockstep".into(),
        format!("{km_secs:.3}"),
        format!("{:.1}", km_ks.len() as f64 / km_secs),
        fmt_x(km_seq_secs / km_secs),
    ]);
    km_table.print("Repeated-cohort K-means (one dataset, six k values)");
    println!(
        "lockstep: {} rounds, {} shared tiles | {} units stolen",
        km_stats.lockstep_rounds, km_stats.lockstep_shared_tiles, km_stats.steals
    );
    // Incremental TI pruning: fraction of all (point x iteration)
    // assignment decisions answered by the carried bounds instead of
    // the device (denominator is the configured iteration cap, so
    // early convergence only makes the reported rate conservative).
    let km_prune_rate = km_stats.points_pruned as f64
        / (n_km * km_iters * km_ks.len()) as f64;
    println!(
        "incremental TI: {} tiles skipped, {} points pruned ({:.1}% of point-iterations), \
         {} bound recomputes",
        km_stats.tiles_skipped,
        km_stats.points_pruned,
        100.0 * km_prune_rate,
        km_stats.bound_recomputes,
    );
    let mut km_row = scenario_row(
        "kmeans_repeated_cohort_2shard",
        km_ks.len(),
        km_secs,
        km_seq_secs / km_secs,
        km_batcher.stats(),
        km_batcher.shard_count(),
    );
    if let Value::Obj(m) = &mut km_row {
        m.insert("prune_rate".to_string(), json::num(km_prune_rate));
    }
    scenarios.push(km_row);

    if km_stats.lockstep_shared_tiles == 0 {
        eprintln!(
            "FAIL: same-dataset kmeans cohort shared no assignment tiles — lockstep regressed"
        );
        std::process::exit(1);
    }
    if km_stats.points_pruned == 0 || km_stats.tiles_skipped == 0 {
        eprintln!(
            "FAIL: multi-iteration kmeans cohort pruned nothing after iteration 1 \
             ({} points pruned, {} tiles skipped) — incremental TI pruning regressed",
            km_stats.points_pruned, km_stats.tiles_skipped
        );
        std::process::exit(1);
    }

    // --- Range-join cohort: GTI group-level pruning on radius queries ------
    // Four radius queries, each submitted twice (dedup answers the
    // repeat from the same execution), against one clustered target.
    // The sources are drawn with the target's generator seed, so they
    // share its cluster centers: every query has real within-threshold
    // matches, while almost every cross-cluster group pair is provably
    // outside the threshold — the group-level lower bound prunes it
    // without touching the device.  Results must stay bit-identical to
    // solo engine calls; the row carries the group-pair prune rate.
    let (n_rj_trg, n_rj_src) = if fast { (4_000, 300) } else { (16_000, 1_200) };
    let rj_t = 0.25f32;
    let rj_trg = Arc::new(synthetic::clustered(n_rj_trg, 8, 32, 0.02, 42));
    let rj_srcs: Vec<Arc<Dataset>> = (0..4)
        .map(|i| Arc::new(synthetic::clustered(n_rj_src + 37 * i, 8, 32, 0.02, 42)))
        .collect();

    let mut engine = Engine::new(cfg.clone()).expect("engine");
    let t = Instant::now();
    let mut rj_seq = Vec::new();
    for src in &rj_srcs {
        rj_seq.push(engine.range_join(src, &rj_trg, rj_t).expect("solo range join"));
    }
    let rj_seq_secs = t.elapsed().as_secs_f64();

    let mut serve_cfg = cfg.serve.clone();
    serve_cfg.shards = 2;
    let mut rj_batcher =
        QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), serve_cfg);
    for src in rj_srcs.iter().chain(rj_srcs.iter()) {
        rj_batcher.submit(ServeRequest::rangejoin(src.clone(), rj_trg.clone(), rj_t));
    }
    let t = Instant::now();
    let rj_out = rj_batcher.flush().expect("range-join flush");
    let rj_secs = t.elapsed().as_secs_f64();
    let (mut rj_pairs, mut rj_surviving, mut rj_matches) = (0u64, 0u64, 0usize);
    for (i, (_, resp)) in rj_out.iter().enumerate() {
        let got = resp.as_rangejoin().expect("range-join response");
        assert_eq!(
            got.neighbors,
            rj_seq[i % rj_srcs.len()].neighbors,
            "batched range join diverged from sequential on query {i}"
        );
        rj_pairs += got.report.filter.group_pairs;
        rj_surviving += got.report.filter.surviving_group_pairs;
        rj_matches += got.neighbors.iter().map(Vec::len).sum::<usize>();
    }
    let rj_stats = rj_batcher.stats();
    let mut rj_table = Table::new(&["path", "wall (s)", "q/s", "speedup"]);
    rj_table.row(vec![
        "sequential range-join calls".into(),
        format!("{rj_seq_secs:.3}"),
        format!("{:.1}", rj_srcs.len() as f64 / rj_seq_secs),
        fmt_x(1.0),
    ]);
    rj_table.row(vec![
        "serve, 2 shards, dedup".into(),
        format!("{rj_secs:.3}"),
        format!("{:.1}", rj_out.len() as f64 / rj_secs),
        fmt_x((rj_seq_secs * 2.0) / rj_secs),
    ]);
    rj_table.print("Range-join cohort (radius queries, duplicates deduplicated)");
    let rj_prune_rate =
        if rj_pairs == 0 { 0.0 } else { 1.0 - rj_surviving as f64 / rj_pairs as f64 };
    println!(
        "range join: {} answered ({} deduplicated), {:.1}% of group pairs pruned by \
         bounds, {} within-threshold matches",
        rj_out.len(),
        rj_stats.dedup_hits,
        100.0 * rj_prune_rate,
        rj_matches,
    );
    let mut rj_row = scenario_row(
        "rangejoin_dedup_2shard",
        rj_out.len(),
        rj_secs,
        (rj_seq_secs * 2.0) / rj_secs.max(1e-12),
        rj_batcher.stats(),
        rj_batcher.shard_count(),
    );
    if let Value::Obj(m) = &mut rj_row {
        m.insert("prune_rate".to_string(), json::num(rj_prune_rate));
    }
    scenarios.push(rj_row);
    if rj_pairs == 0 || rj_surviving >= rj_pairs {
        eprintln!(
            "FAIL: range-join cohort pruned no group pairs ({rj_surviving} of {rj_pairs} \
             survived) — group-level threshold pruning regressed"
        );
        std::process::exit(1);
    }
    if rj_matches == 0 {
        eprintln!(
            "FAIL: range-join cohort emitted no within-threshold pairs — the scenario no \
             longer exercises emission"
        );
        std::process::exit(1);
    }

    // --- Latency scenario: EDF placement under generous deadlines ---------
    // Every query carries a deadline far beyond what serving needs
    // (capacity-sufficient by construction), staggered so the EDF
    // planner sees distinct urgency tiers.  Met/missed is judged at
    // service start, so this pre-deadline flush cannot miss by
    // construction — the smoke gate below is an ACCOUNTING guard: it
    // fails CI if the deadline bookkeeping ever loses or miscounts an
    // outcome on the capacity-sufficient path (every query must
    // resolve to met, none to missed); the completion tail is
    // reported through the latency percentiles.
    let mut serve_cfg = cfg.serve.clone();
    serve_cfg.shards = 2;
    serve_cfg.placement = "edf-lpt".to_string();
    let mut lat_batcher =
        QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), serve_cfg);
    for (i, (src, trg)) in queries.iter().enumerate() {
        let deadline = Duration::from_secs(60 + 10 * i as u64);
        lat_batcher.submit_with_deadline(
            ServeRequest::knn(src.clone(), trg.clone(), k),
            deadline,
        );
    }
    let t = Instant::now();
    let lat_out = lat_batcher.flush().expect("latency flush");
    let lat_secs = t.elapsed().as_secs_f64();
    for (i, (_, resp)) in lat_out.iter().enumerate() {
        let got = resp.as_knn().expect("knn response");
        assert_eq!(
            got.neighbors, seq_results[i].neighbors,
            "deadline-aware placement diverged from sequential on query {i}"
        );
    }
    let lat_stats = lat_batcher.stats();
    let (lat_p50, lat_p95, lat_p99) = lat_stats.latency_percentiles_ms();
    println!(
        "\nlatency scenario (edf-lpt, 2 shards): p50 {lat_p50:.3} ms / p95 {lat_p95:.3} ms / \
         p99 {lat_p99:.3} ms | {} met / {} missed",
        lat_stats.deadline_met, lat_stats.deadline_misses,
    );
    scenarios.push(scenario_row(
        "knn_deadline_edf_2shard",
        queries.len(),
        lat_secs,
        seq_secs / lat_secs.max(1e-12),
        lat_batcher.stats(),
        lat_batcher.shard_count(),
    ));
    if lat_stats.deadline_misses > 0 || lat_stats.deadline_met != queries.len() as u64 {
        eprintln!(
            "FAIL: deadline accounting regressed on the capacity-sufficient EDF scenario \
             ({} met / {} missed, expected {} met / 0 missed)",
            lat_stats.deadline_met,
            lat_stats.deadline_misses,
            queries.len()
        );
        std::process::exit(1);
    }

    // --- Open-loop arrival traces through the always-on Server ------------
    // The same 12 KNN queries, now arriving on a schedule instead of
    // pre-loaded: the producer jumps a VirtualClock to each arrival
    // tick and submits WITHOUT waiting for earlier responses (open
    // loop — arrivals do not slow down when the server does).  The
    // scheduler thread coalesces whatever has arrived by each
    // deadline expiry, so one trace exercises many wake-ups, partial
    // batches and drain-on-shutdown.  Two canned traces, both seeded
    // and fully deterministic:
    //   poisson — exponential inter-arrivals, ~2 ms mean;
    //   burst   — 4-query bursts every 10 ms (arrival spikes).
    let poisson_trace: Vec<u64> = {
        let mut rng = Rng::new(0xA221_7A1E);
        let mut at = 0u64;
        (0..queries.len())
            .map(|_| {
                at += (-(1.0 - rng.f64()).ln() * 2_000_000.0) as u64 + 1;
                at
            })
            .collect()
    };
    let burst_trace: Vec<u64> =
        (0..queries.len()).map(|i| (i / 4) as u64 * 10_000_000).collect();
    let mut open_table = Table::new(&["trace", "wall (s)", "q/s", "p99 (ms)", "flushes"]);
    for (trace_name, trace) in [("poisson", &poisson_trace), ("burst", &burst_trace)] {
        let mut serve_cfg = cfg.serve.clone();
        serve_cfg.shards = 2;
        let clock = VirtualClock::new();
        let server = Server::with_clock(
            Engine::new(cfg.clone()).expect("engine"),
            serve_cfg,
            Arc::new(clock.clone()),
        );
        let t = Instant::now();
        let mut handles = Vec::new();
        for (i, (src, trg)) in queries.iter().enumerate() {
            clock.set(trace[i]);
            let handle = server
                .submit_with_deadline(
                    ServeRequest::knn(src.clone(), trg.clone(), k),
                    Duration::from_millis(50),
                )
                .expect("accepted under default cap");
            handles.push(handle);
        }
        // Expire every deadline, then collect and drain.
        clock.advance(Duration::from_millis(100));
        let responses: Vec<_> =
            handles.into_iter().map(|h| h.wait().expect("served")).collect();
        let secs = t.elapsed().as_secs_f64();
        let shards = server.shard_count();
        let stats = server.shutdown();
        for (i, resp) in responses.iter().enumerate() {
            let got = resp.as_knn().expect("knn response");
            assert_eq!(
                got.neighbors, seq_results[i].neighbors,
                "open-loop {trace_name} trace diverged from sequential on query {i}"
            );
        }
        if stats.latency_ns.len() != queries.len() || stats.shed != 0 {
            eprintln!(
                "FAIL: open-loop {trace_name} trace lost queries ({} answered of {}, {} shed)",
                stats.latency_ns.len(),
                queries.len(),
                stats.shed
            );
            std::process::exit(1);
        }
        let (_, _, p99) = stats.latency_percentiles_ms();
        open_table.row(vec![
            trace_name.into(),
            format!("{secs:.3}"),
            format!("{:.1}", q / secs),
            format!("{p99:.3}"),
            format!("{}", stats.flushes),
        ]);
        scenarios.push(scenario_row(
            &format!("knn_openloop_{trace_name}_2shard"),
            queries.len(),
            secs,
            seq_secs / secs.max(1e-12),
            &stats,
            shards,
        ));
    }
    open_table.print("Open-loop arrival traces (always-on Server, 2 shards, virtual clock)");

    // --- Emulated multi-device: double-buffered transfer/compute overlap ---
    // Four distinct cold targets, two shards pinned round-robin onto
    // two emulated devices: each shard plans two cohorts per flush, so
    // the second cohort's cold slab upload is modeled on the device's
    // DMA channel while the first cohort's tiles are still computing
    // (`serve.overlap`).  Results must stay bit-identical to solo
    // calls — the device model only changes the timeline counters.
    let trg_c = Arc::new(synthetic::clustered(n_trg, 8, 40, 0.02, 3));
    let trg_d = Arc::new(synthetic::clustered(n_trg / 2, 8, 20, 0.02, 4));
    let md_targets = [trg_a.clone(), trg_b.clone(), trg_c, trg_d];
    let md_queries: Vec<(Arc<Dataset>, Arc<Dataset>)> = (0..12)
        .map(|i| (srcs[i % 6].clone(), md_targets[i % 4].clone()))
        .collect();
    let mut engine = Engine::new(cfg.clone()).expect("engine");
    let t = Instant::now();
    let mut md_seq = Vec::new();
    for (src, trg) in &md_queries {
        md_seq.push(engine.knn_join(src, trg, k).expect("solo knn"));
    }
    let md_seq_secs = t.elapsed().as_secs_f64();

    let mut serve_cfg = cfg.serve.clone();
    serve_cfg.shards = 2;
    serve_cfg.devices = 2;
    let mut md_batcher =
        QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), serve_cfg);
    for (src, trg) in &md_queries {
        md_batcher.submit(ServeRequest::knn(src.clone(), trg.clone(), k));
    }
    let t = Instant::now();
    let md_out = md_batcher.flush().expect("multi-device flush");
    let md_secs = t.elapsed().as_secs_f64();
    for (i, (_, resp)) in md_out.iter().enumerate() {
        let got = resp.as_knn().expect("knn response");
        assert_eq!(
            got.neighbors, md_seq[i].neighbors,
            "multi-device serving diverged from sequential on query {i}"
        );
    }
    let md_stats = md_batcher.stats();
    println!(
        "\nmulti-device scenario ({} devices, {} shards): modeled {:.3} ms transfer / \
         {:.3} ms compute, {:.3} ms overlapped",
        md_batcher.device_count(),
        md_batcher.shard_count(),
        md_stats.transfer_ns as f64 / 1e6,
        md_stats.compute_ns as f64 / 1e6,
        md_stats.overlap_ns as f64 / 1e6,
    );
    scenarios.push(scenario_row(
        "knn_multidevice_2dev_2shard",
        md_queries.len(),
        md_secs,
        md_seq_secs / md_secs.max(1e-12),
        md_batcher.stats(),
        md_batcher.shard_count(),
    ));
    if md_stats.transfer_ns == 0 || md_stats.overlap_ns == 0 {
        eprintln!(
            "FAIL: 2-device flush with two cold cohorts per shard modeled {} ns transfer / \
             {} ns overlap — double-buffered transfer/compute overlap regressed",
            md_stats.transfer_ns, md_stats.overlap_ns
        );
        std::process::exit(1);
    }

    // --- Movement-aware LPT vs blind LPT on a warm repeating workload ------
    // Two equal-cost cohorts (same-size targets, identical source)
    // repeat over several flushes with their submission order
    // alternating.  Blind LPT breaks the cost tie by submission order,
    // so each cohort bounces between shards every flush; the
    // movement-aware planner charges the bounce its modeled DMA cost
    // and keeps each cohort on the shard that already holds its slabs.
    // Each emulated device is sized to ~1.5x ONE cohort's working set,
    // so a bounce is a real slab rebuild, not a cache hit.  Stealing
    // is disabled so the comparison isolates placement.
    let trg_w: Vec<Arc<Dataset>> = (0..2u64)
        .map(|i| Arc::new(synthetic::clustered(n_trg * 2, 32, 50, 0.02, 11 + i)))
        .collect();
    let w_src = Arc::new(synthetic::clustered(n_src / 4, 32, 10, 0.03, 200));
    let w_queries: Vec<(Arc<Dataset>, Arc<Dataset>)> =
        (0..2).map(|i| (w_src.clone(), trg_w[i].clone())).collect();
    let mut engine = Engine::new(cfg.clone()).expect("engine");
    let t = Instant::now();
    let mut w_seq = Vec::new();
    for (src, trg) in &w_queries {
        w_seq.push(engine.knn_join(src, trg, k).expect("solo knn"));
    }
    let w_seq_secs = t.elapsed().as_secs_f64();

    // Probe one cohort's resident slab footprint so the A/B runs can
    // size the emulated device memory around it.
    let mut probe_cfg = cfg.serve.clone();
    probe_cfg.shards = 1;
    probe_cfg.slab_cache_bytes = 1 << 30;
    let mut probe = QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), probe_cfg);
    probe.submit(ServeRequest::knn(w_queries[0].0.clone(), w_queries[0].1.clone(), k));
    probe.flush().expect("probe flush");
    let one_cohort_bytes = probe.stats().slab_cache_bytes as usize;

    let w_rounds = if fast { 5 } else { 8 };
    let mut w_qps = [0.0f64; 2]; // [blind LPT, movement-aware LPT]
    let mut w_miss = [0u64; 2]; // warm-round slab misses
    for (slot, movement_aware) in [(0usize, false), (1usize, true)] {
        let mut serve_cfg = cfg.serve.clone();
        serve_cfg.shards = 2;
        serve_cfg.devices = 2;
        serve_cfg.placement = "lpt".to_string();
        serve_cfg.movement_aware = movement_aware;
        serve_cfg.steal_threshold = 0;
        serve_cfg.slab_cache_bytes = 1 << 30;
        serve_cfg.device_mem_bytes = one_cohort_bytes + one_cohort_bytes / 2;
        let mut b = QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), serve_cfg);
        let mut warm_secs = 0.0f64;
        let mut warm_queries = 0usize;
        let mut miss0 = 0u64;
        for round in 0..w_rounds {
            // Alternate submission order so blind LPT's tie-break flips.
            let order: Vec<usize> = if round % 2 == 0 { vec![0, 1] } else { vec![1, 0] };
            for &qi in &order {
                let (src, trg) = &w_queries[qi];
                b.submit(ServeRequest::knn(src.clone(), trg.clone(), k));
            }
            let t = Instant::now();
            let out = b.flush().expect("warmth flush");
            let secs = t.elapsed().as_secs_f64();
            for (j, (_, resp)) in out.iter().enumerate() {
                let got = resp.as_knn().expect("knn response");
                assert_eq!(
                    got.neighbors,
                    w_seq[order[j]].neighbors,
                    "warmth A/B (movement_aware={movement_aware}) diverged on round {round}"
                );
            }
            if round == 0 {
                miss0 = b.stats().slab_cache_misses;
            } else {
                warm_secs += secs;
                warm_queries += order.len();
            }
        }
        w_qps[slot] = warm_queries as f64 / warm_secs.max(1e-12);
        w_miss[slot] = b.stats().slab_cache_misses - miss0;
        scenarios.push(scenario_row(
            if movement_aware {
                "knn_warmth_lpt_2dev_2shard"
            } else {
                "knn_movement_blind_lpt_2dev_2shard"
            },
            warm_queries,
            warm_secs,
            (w_seq_secs * (w_rounds - 1) as f64) / warm_secs.max(1e-12),
            b.stats(),
            b.shard_count(),
        ));
    }
    let mut w_table = Table::new(&["placement", "warm q/s", "warm slab misses"]);
    w_table.row(vec![
        "blind LPT".into(),
        format!("{:.1}", w_qps[0]),
        format!("{}", w_miss[0]),
    ]);
    w_table.row(vec![
        "movement-aware LPT".into(),
        format!("{:.1}", w_qps[1]),
        format!("{}", w_miss[1]),
    ]);
    w_table.print("Warmth A/B: repeating cohorts on memory-constrained devices");
    if w_miss[1] >= w_miss[0] {
        eprintln!(
            "FAIL: movement-aware LPT rebuilt as many slabs as blind LPT on warm rounds \
             ({} vs {}) — warmth-aware placement regressed",
            w_miss[1], w_miss[0]
        );
        std::process::exit(1);
    }
    if w_qps[1] < w_qps[0] {
        eprintln!(
            "FAIL: movement-aware LPT slower than movement-blind LPT on the slab-heavy \
             repeated-cohort workload ({:.1} vs {:.1} warm q/s)",
            w_qps[1], w_qps[0]
        );
        std::process::exit(1);
    }

    // --- Sustained overload: reject policy at a tiny queue_cap -------------
    // 12 queries burst in at one virtual instant against queue_cap=4
    // under `overload="reject"`: the first four are accepted, the
    // rest are shed at submit with an error (no silent drops), and
    // the shed count lands in the stats row the regression guard
    // checks.  Accepted queries must still answer bit-identically.
    let mut serve_cfg = cfg.serve.clone();
    serve_cfg.shards = 2;
    serve_cfg.queue_cap = 4;
    serve_cfg.overload = "reject".to_string();
    let clock = VirtualClock::new();
    let server = Server::with_clock(
        Engine::new(cfg.clone()).expect("engine"),
        serve_cfg,
        Arc::new(clock.clone()),
    );
    let t = Instant::now();
    let mut accepted = Vec::new();
    let mut rejected = 0usize;
    for (i, (src, trg)) in queries.iter().enumerate() {
        match server.submit_with_deadline(
            ServeRequest::knn(src.clone(), trg.clone(), k),
            Duration::from_millis(50),
        ) {
            Ok(handle) => accepted.push((i, handle)),
            Err(_) => rejected += 1,
        }
    }
    clock.advance(Duration::from_millis(100));
    let answered: Vec<_> = accepted
        .into_iter()
        .map(|(i, h)| (i, h.wait().expect("accepted query served")))
        .collect();
    let ov_secs = t.elapsed().as_secs_f64();
    let ov_shards = server.shard_count();
    let ov_stats = server.shutdown();
    for (i, resp) in &answered {
        let got = resp.as_knn().expect("knn response");
        assert_eq!(
            got.neighbors, seq_results[*i].neighbors,
            "overload scenario diverged from sequential on accepted query {i}"
        );
    }
    println!(
        "\noverload scenario (reject @ queue_cap=4): {} offered, {} answered, {} shed \
         ({:.0}% shed rate)",
        queries.len(),
        answered.len(),
        ov_stats.shed,
        100.0 * ov_stats.shed as f64 / queries.len() as f64,
    );
    let mut ov_row = scenario_row(
        "knn_overload_reject_2shard",
        queries.len(),
        ov_secs,
        0.0,
        &ov_stats,
        ov_shards,
    );
    if let Value::Obj(m) = &mut ov_row {
        m.insert(
            "shed_rate".to_string(),
            json::num(ov_stats.shed as f64 / queries.len() as f64),
        );
    }
    scenarios.push(ov_row);
    if ov_stats.shed == 0 || rejected == 0 || ov_stats.shed != rejected as u64 {
        eprintln!(
            "FAIL: overload burst past queue_cap shed nothing (or stats disagree with \
             submit errors: {} shed vs {} rejected) — reject backpressure regressed",
            ov_stats.shed, rejected
        );
        std::process::exit(1);
    }
    if ov_stats.flush_failures != 0 || ov_stats.latency_ns.len() != answered.len() {
        eprintln!(
            "FAIL: overload scenario lost accepted queries ({} answered of {} accepted, \
             {} flush failures)",
            ov_stats.latency_ns.len(),
            answered.len(),
            ov_stats.flush_failures
        );
        std::process::exit(1);
    }

    // --- Saturated diurnal arrivals: predictive shedding vs reactive -------
    // A diurnal load curve on the virtual clock: peak phases offer
    // twice the trough arrivals (Poisson-jittered inter-arrival gaps),
    // and each peak's tail arrivals carry deadlines that have already
    // expired by the time the saturated service point flushes (1 ms
    // later).  The reactive baseline executes those queries anyway and
    // serves them late — deadline misses that burn device time for
    // nothing.  With `serve.predictive_shed` the calibrated admission
    // check sheds exactly the already-doomed queries before
    // partitioning, so the predictive row must shed > 0 and miss
    // strictly less than the reactive row while every served response
    // stays bit-identical to the solo engine.
    let di_rounds = if fast { 4 } else { 8 };
    let mut di_met = [0u64; 2]; // [reactive, predictive]
    let mut di_misses = [0u64; 2];
    let mut di_sheds = [0u64; 2];
    for (slot, predictive) in [(0usize, false), (1usize, true)] {
        let mut serve_cfg = cfg.serve.clone();
        serve_cfg.shards = 2;
        serve_cfg.predictive_shed = predictive;
        let clock = VirtualClock::new();
        let mut b = QueryBatcher::with_clock(
            Engine::new(cfg.clone()).expect("engine"),
            serve_cfg,
            Arc::new(clock.clone()),
        );
        let mut rng = Rng::new(0xD1_0C4A);
        let mut offered = 0usize;
        let mut served = 0usize;
        let mut wall = 0.0f64;
        for round in 0..di_rounds {
            let peak = round % 2 == 0;
            let arrivals: &[usize] = if peak { &[0, 1, 2, 3, 4, 5] } else { &[0, 2, 4] };
            let mut expected: Vec<usize> = Vec::new();
            let mut tight_count = 0usize;
            for (j, &qi) in arrivals.iter().enumerate() {
                let gap = (-(1.0 - rng.f64()).ln() * 150_000.0) as u64 + 1;
                clock.advance(Duration::from_nanos(gap));
                // Peak tails are already hopeless: their deadline
                // expires before the flush below even starts.
                let tight = peak && j >= arrivals.len() / 2;
                let deadline = if tight {
                    tight_count += 1;
                    Duration::from_micros(100)
                } else {
                    expected.push(qi);
                    Duration::from_millis(20)
                };
                let (src, trg) = &queries[qi];
                b.submit_with_deadline(ServeRequest::knn(src.clone(), trg.clone(), k), deadline);
                offered += 1;
            }
            clock.advance(Duration::from_millis(1));
            let t = Instant::now();
            let out = b.flush().expect("diurnal flush");
            wall += t.elapsed().as_secs_f64();
            let shed_ids = b.take_predicted_sheds();
            let want: &[usize] = if predictive { expected.as_slice() } else { arrivals };
            assert_eq!(
                (out.len(), shed_ids.len()),
                (want.len(), if predictive { tight_count } else { 0 }),
                "diurnal round {round} (predictive={predictive}) lost or duplicated queries"
            );
            for ((_, resp), &qi) in out.iter().zip(want) {
                let got = resp.as_knn().expect("knn response");
                assert_eq!(
                    got.neighbors, seq_results[qi].neighbors,
                    "diurnal trace (predictive={predictive}) diverged from sequential on \
                     query {qi}"
                );
            }
            served += out.len();
        }
        di_met[slot] = b.stats().deadline_met;
        di_misses[slot] = b.stats().deadline_misses;
        di_sheds[slot] = b.stats().predicted_sheds;
        scenarios.push(scenario_row(
            if predictive {
                "knn_diurnal_predictive_2shard"
            } else {
                "knn_diurnal_reactive_2shard"
            },
            offered,
            wall,
            (seq_secs / q * served as f64) / wall.max(1e-12),
            b.stats(),
            b.shard_count(),
        ));
    }
    println!(
        "\ndiurnal scenario (2 shards): reactive {} met / {} missed / {} shed; \
         predictive {} met / {} missed / {} shed",
        di_met[0], di_misses[0], di_sheds[0], di_met[1], di_misses[1], di_sheds[1],
    );
    if di_sheds[1] == 0 || di_sheds[0] != 0 {
        eprintln!(
            "FAIL: predictive shedding misfired on the saturated diurnal trace \
             ({} predictive-run sheds, {} reactive-run sheds; expected > 0 and 0) — \
             early deadline shedding regressed",
            di_sheds[1], di_sheds[0]
        );
        std::process::exit(1);
    }
    if di_misses[1] >= di_misses[0] {
        eprintln!(
            "FAIL: predictive shedding did not reduce deadline misses on the saturated \
             diurnal trace ({} vs reactive {}) — predictive admission regressed",
            di_misses[1], di_misses[0]
        );
        std::process::exit(1);
    }

    // --- Devices x DMA-bandwidth frontier (modeled) -------------------------
    // Sweep `serve.devices` x `serve.dma_gbps` through the same Eq. 5
    // multi-device latency model the serving timeline charges, so the
    // JSON artifact records which device count / link speed the
    // analytical model would buy next for this bench's workload shape.
    // Purely modeled: deterministic, host-independent, record-only in
    // the regression baseline.
    let frontier = Explorer::default().device_frontier(
        &DseWorkload { src_size: n_src, trg_size: n_trg, d: 8, n_iteration: 1, alpha: 10.0 },
        &DesignConfig { n_src_grp: 10, n_trg_grp: 8, block: 64, simd: 4, unroll: 4 },
        &[1, 2, 4],
        &[4.0, 16.0],
    );
    let mut fr_table = Table::new(&["devices", "dma (gbps)", "modeled latency (ms)", "wkld/s"]);
    for p in &frontier {
        fr_table.row(vec![
            format!("{}", p.devices),
            format!("{:.0}", p.dma_gbps),
            format!("{:.3}", p.latency_secs * 1e3),
            format!("{:.1}", p.throughput),
        ]);
    }
    fr_table.print("Modeled devices x DMA-bandwidth frontier (Eq. 5 multi-device)");
    let fr_best = frontier
        .iter()
        .max_by(|a, b| a.throughput.partial_cmp(&b.throughput).expect("finite model"))
        .expect("non-empty sweep");
    scenarios.push(json::obj(vec![
        ("name", json::s("devices_vs_throughput_frontier".to_string())),
        (
            "frontier",
            Value::Arr(
                frontier
                    .iter()
                    .map(|p| {
                        json::obj(vec![
                            ("devices", json::num(p.devices as f64)),
                            ("dma_gbps", json::num(p.dma_gbps)),
                            ("latency_secs", json::num(p.latency_secs)),
                            ("throughput", json::num(p.throughput)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("best_devices", json::num(fr_best.devices as f64)),
        ("best_dma_gbps", json::num(fr_best.dma_gbps)),
        ("best_throughput", json::num(fr_best.throughput)),
    ]));

    // --- Machine-readable output ------------------------------------------
    let out_path = std::env::var("ACCD_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let doc = json::obj(vec![
        ("bench", json::s("serve_throughput".to_string())),
        ("fast_mode", Value::Bool(fast)),
        ("sequential_knn_secs", json::num(seq_secs)),
        ("sequential_kmeans_secs", json::num(km_seq_secs)),
        ("scenarios", Value::Arr(scenarios)),
    ]);
    std::fs::write(&out_path, doc.to_string()).expect("write bench json");
    println!("\nwrote {out_path}");

    println!(
        "\ntiles shared: {}/{} ({:.1}%) | grouping cache hit rate {:.1}% | \
         slab cache hit rate {:.1}% ({} evictions)",
        stats.tiles_shared,
        stats.tiles_total,
        100.0 * stats.tiles_shared_ratio(),
        100.0 * stats.cache_hit_rate(),
        100.0 * stats.slab_hit_rate(),
        stats.slab_cache_evictions,
    );
}
