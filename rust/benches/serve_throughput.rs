//! Serving-throughput bench: N coalescible queries through
//! `serve::QueryBatcher` vs the same N queries as independent `Engine`
//! calls.
//!
//! The batched path amortizes exactly what a serving deployment
//! amortizes: the target grouping is built once per cohort instead of
//! once per query, packed target slabs are shared across queries with
//! identical candidate sets, and duplicated queries are answered from
//! one execution.  `ServeStats` reports the tiles-shared ratio that
//! proves the coalescing happened.
//!
//! Scale down with ACCD_BENCH_FAST=1 (CI).

use std::sync::Arc;
use std::time::Instant;

use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::{synthetic, Dataset};
use accd::serve::{QueryBatcher, ServeRequest};
use accd::util::bench::{fmt_x, Table};

fn main() {
    let fast = std::env::var("ACCD_BENCH_FAST").as_deref() == Ok("1");
    let (n_trg, n_src) = if fast { (4_000, 300) } else { (20_000, 1_500) };
    let k = 10;

    // One hot target dataset, 6 distinct user queries, each submitted
    // twice (live traffic repeats itself) -> 12 coalescible queries.
    let trg = Arc::new(synthetic::clustered(n_trg, 8, 50, 0.02, 1));
    let srcs: Vec<Arc<Dataset>> = (0..6)
        .map(|i| Arc::new(synthetic::clustered(n_src, 8, 10, 0.03, 100 + i as u64)))
        .collect();
    let queries: Vec<Arc<Dataset>> = (0..12).map(|i| srcs[i % 6].clone()).collect();
    eprintln!(
        "serve_throughput: {} KNN queries (6 unique) x k={k} against one {}-point target",
        queries.len(),
        n_trg
    );

    let cfg = AccdConfig::new();

    // --- Sequential: one Engine call per query --------------------------
    let mut engine = Engine::new(cfg.clone()).expect("engine");
    let t = Instant::now();
    let mut seq_results = Vec::new();
    for src in &queries {
        seq_results.push(engine.knn_join(src, &trg, k).expect("solo knn"));
    }
    let seq_secs = t.elapsed().as_secs_f64();

    // --- Batched: one flush through the serving runtime ------------------
    let mut batcher =
        QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), cfg.serve.clone());
    for src in &queries {
        batcher.submit(ServeRequest::knn(src.clone(), trg.clone(), k));
    }
    let t = Instant::now();
    let batched = batcher.flush().expect("flush");
    let bat_secs = t.elapsed().as_secs_f64();

    // --- Batched again (warm grouping cache: steady-state serving) -------
    for src in &queries {
        batcher.submit(ServeRequest::knn(src.clone(), trg.clone(), k));
    }
    let t = Instant::now();
    let _ = batcher.flush().expect("warm flush");
    let warm_secs = t.elapsed().as_secs_f64();

    // Parity spot-check: the bench never reports a win on wrong answers.
    for (i, (_, resp)) in batched.iter().enumerate() {
        let got = resp.as_knn().expect("knn response");
        assert_eq!(
            got.neighbors, seq_results[i].neighbors,
            "batched result diverged from sequential on query {i}"
        );
    }

    let stats = batcher.stats();
    let mut table = Table::new(&["path", "wall (s)", "q/s", "speedup"]);
    let q = queries.len() as f64;
    table.row(vec![
        "sequential Engine calls".into(),
        format!("{seq_secs:.3}"),
        format!("{:.1}", q / seq_secs),
        fmt_x(1.0),
    ]);
    table.row(vec![
        "serve (cold cache)".into(),
        format!("{bat_secs:.3}"),
        format!("{:.1}", q / bat_secs),
        fmt_x(seq_secs / bat_secs),
    ]);
    table.row(vec![
        "serve (warm cache)".into(),
        format!("{warm_secs:.3}"),
        format!("{:.1}", q / warm_secs),
        fmt_x(seq_secs / warm_secs),
    ]);
    table.print("Batched serving vs sequential engine calls");
    println!("\n{}", stats.summary());

    if stats.tiles_shared == 0 {
        eprintln!("FAIL: coalescible queries shared no tiles — coalescing regressed");
        std::process::exit(1);
    }
    if bat_secs >= seq_secs {
        eprintln!(
            "WARN: batched ({bat_secs:.3}s) did not beat sequential ({seq_secs:.3}s) \
             on this machine/scale"
        );
    }
    println!(
        "\ntiles shared: {}/{} ({:.1}%) | grouping cache hit rate {:.1}%",
        stats.tiles_shared,
        stats.tiles_total,
        100.0 * stats.tiles_shared_ratio(),
        100.0 * stats.cache_hit_rate(),
    );
}
