//! Serving-throughput bench: N coalescible queries through
//! `serve::QueryBatcher` vs the same N queries as independent `Engine`
//! calls — swept across engine-shard counts (1/2/4), plus a
//! repeated-flush scenario that shows the persistent per-shard slab
//! cache converting packing work into cache hits.
//!
//! The batched path amortizes exactly what a serving deployment
//! amortizes: the target grouping is built once per cohort instead of
//! once per query, packed target slabs are shared across queries with
//! identical candidate sets (and across flushes, until LRU-evicted
//! over the byte budget), duplicated queries are answered from one
//! execution, and independent cohorts run concurrently on the engine
//! pool.  `ServeStats` reports the sharing that proves it happened.
//!
//! Scale down with ACCD_BENCH_FAST=1 (CI).

use std::sync::Arc;
use std::time::Instant;

use accd::config::AccdConfig;
use accd::coordinator::Engine;
use accd::data::{synthetic, Dataset};
use accd::serve::{QueryBatcher, ServeRequest};
use accd::util::bench::{fmt_x, Table};

fn main() {
    let fast = std::env::var("ACCD_BENCH_FAST").as_deref() == Ok("1");
    let (n_trg, n_src) = if fast { (4_000, 300) } else { (20_000, 1_500) };
    let k = 10;

    // Two hot target datasets, 6 distinct user queries, each submitted
    // twice (live traffic repeats itself) -> 12 coalescible queries in
    // two independent cohorts (so a second shard has work to steal).
    let trg_a = Arc::new(synthetic::clustered(n_trg, 8, 50, 0.02, 1));
    let trg_b = Arc::new(synthetic::clustered(n_trg / 2, 8, 30, 0.02, 2));
    let srcs: Vec<Arc<Dataset>> = (0..6)
        .map(|i| Arc::new(synthetic::clustered(n_src, 8, 10, 0.03, 100 + i as u64)))
        .collect();
    let queries: Vec<(Arc<Dataset>, Arc<Dataset>)> = (0..12)
        .map(|i| (srcs[i % 6].clone(), if i % 2 == 0 { trg_a.clone() } else { trg_b.clone() }))
        .collect();
    eprintln!(
        "serve_throughput: {} KNN queries (6 unique sources, 2 cohorts) x k={k} \
         against {}/{}-point targets",
        queries.len(),
        n_trg,
        n_trg / 2
    );

    let cfg = AccdConfig::new();
    let q = queries.len() as f64;

    // --- Sequential: one Engine call per query --------------------------
    let mut engine = Engine::new(cfg.clone()).expect("engine");
    let t = Instant::now();
    let mut seq_results = Vec::new();
    for (src, trg) in &queries {
        seq_results.push(engine.knn_join(src, trg, k).expect("solo knn"));
    }
    let seq_secs = t.elapsed().as_secs_f64();

    // --- Shard sweep: one flush through 1/2/4-shard pools ----------------
    let mut table = Table::new(&["path", "wall (s)", "q/s", "speedup"]);
    table.row(vec![
        "sequential Engine calls".into(),
        format!("{seq_secs:.3}"),
        format!("{:.1}", q / seq_secs),
        fmt_x(1.0),
    ]);
    let mut any_shared = false;
    for shards in [1usize, 2, 4] {
        let mut serve_cfg = cfg.serve.clone();
        serve_cfg.shards = shards;
        let mut batcher =
            QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), serve_cfg);
        for (src, trg) in &queries {
            batcher.submit(ServeRequest::knn(src.clone(), trg.clone(), k));
        }
        let t = Instant::now();
        let batched = batcher.flush().expect("flush");
        let secs = t.elapsed().as_secs_f64();

        // Parity spot-check: never report a win on wrong answers.
        for (i, (_, resp)) in batched.iter().enumerate() {
            let got = resp.as_knn().expect("knn response");
            assert_eq!(
                got.neighbors, seq_results[i].neighbors,
                "batched result diverged from sequential on query {i} ({shards} shards)"
            );
        }
        any_shared |= batcher.stats().tiles_shared > 0;
        table.row(vec![
            format!("serve, {shards} shard(s), cold"),
            format!("{secs:.3}"),
            format!("{:.1}", q / secs),
            fmt_x(seq_secs / secs),
        ]);
    }
    table.print("Batched serving vs sequential engine calls (shard sweep)");

    // --- Repeated flushes: the persistent slab cache at work -------------
    let rounds = if fast { 3 } else { 5 };
    let mut serve_cfg = cfg.serve.clone();
    serve_cfg.shards = 2;
    let mut batcher = QueryBatcher::new(Engine::new(cfg.clone()).expect("engine"), serve_cfg);
    let mut round_rows = Table::new(&["flush", "wall (s)", "q/s", "slab hit rate"]);
    for round in 0..rounds {
        for (src, trg) in &queries {
            batcher.submit(ServeRequest::knn(src.clone(), trg.clone(), k));
        }
        let hits0 = batcher.stats().slab_cache_hits;
        let misses0 = batcher.stats().slab_cache_misses;
        let t = Instant::now();
        batcher.flush().expect("repeated flush");
        let secs = t.elapsed().as_secs_f64();
        let (hits, misses) = (
            batcher.stats().slab_cache_hits - hits0,
            batcher.stats().slab_cache_misses - misses0,
        );
        let rate = if hits + misses == 0 { 0.0 } else { hits as f64 / (hits + misses) as f64 };
        round_rows.row(vec![
            format!("{}", round + 1),
            format!("{secs:.3}"),
            format!("{:.1}", q / secs),
            format!("{:.1}%", 100.0 * rate),
        ]);
    }
    round_rows.print("Repeated flushes (2 shards): persistent slab cache");
    let stats = batcher.stats();
    println!("\n{}", stats.summary());

    if !any_shared || stats.tiles_shared == 0 {
        eprintln!("FAIL: coalescible queries shared no tiles — coalescing regressed");
        std::process::exit(1);
    }
    if stats.slab_cache_hits == 0 {
        eprintln!("FAIL: repeated flushes hit no cached slabs — persistence regressed");
        std::process::exit(1);
    }
    println!(
        "\ntiles shared: {}/{} ({:.1}%) | grouping cache hit rate {:.1}% | \
         slab cache hit rate {:.1}% ({} evictions)",
        stats.tiles_shared,
        stats.tiles_total,
        100.0 * stats.tiles_shared_ratio(),
        100.0 * stats.cache_hit_rate(),
        100.0 * stats.slab_hit_rate(),
        stats.slab_cache_evictions,
    );
}
