//! Fig. 10 — AccD performance-benefit breakdown on K-means:
//! TOP (CPU), TOP (CPU-FPGA), AccD (CPU), AccD (CPU-FPGA), all
//! normalized to the naive CPU baseline.
//!
//! The paper's finding this bench reproduces: point-level TI (TOP)
//! ported to the accelerator *loses* ground (divergent candidate sets
//! defeat dense tiling), while coarse GTI gains a large factor there
//! — the co-design argument in one table.  Paper averages: TOP CPU
//! 3.77x, TOP CPU-FPGA 2.63x, AccD CPU 2.69x, AccD CPU-FPGA 37.37x.

use accd::data::tablev;
use accd::figures;
use accd::util::bench::{fmt_x, Table};
use accd::util::geomean;

fn main() {
    let scale = figures::bench_scale();
    eprintln!("fig10: K-means breakdown at scale {scale}");
    let rows = match figures::fig10_breakdown(scale) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("fig10 failed (run `make artifacts`?): {e}");
            std::process::exit(1);
        }
    };
    let speedups = figures::speedups(&rows);
    let modeled = figures::modeled_speedups(&rows);
    let impls = ["top_cpu", "top_fpga", "accd_cpu", "accd_fpga"];
    let mut table = Table::new(&[
        "dataset",
        "TOP (CPU)",
        "TOP (CPU-FPGA)",
        "AccD (CPU)",
        "AccD (CPU-FPGA)",
        "AccD (DE10 model)",
    ]);
    let mut per_impl: std::collections::HashMap<&str, Vec<f64>> = Default::default();
    for spec in &tablev::kmeans_datasets() {
        let mut cells = vec![spec.name.to_string()];
        for imp in impls {
            let s = speedups
                .iter()
                .find(|(d, i, _)| d == spec.name && i == imp)
                .map(|(_, _, s)| *s)
                .unwrap_or(f64::NAN);
            per_impl.entry(imp).or_default().push(s);
            cells.push(fmt_x(s));
        }
        let am = modeled
            .iter()
            .find(|(d, i, _)| d == spec.name && i == "accd_fpga")
            .map(|(_, _, s)| *s)
            .unwrap_or(f64::NAN);
        per_impl.entry("accd_model").or_default().push(am);
        cells.push(fmt_x(am));
        table.row(cells);
    }
    let mut geo = vec!["geomean".to_string()];
    for imp in impls {
        geo.push(fmt_x(geomean(&per_impl[imp])));
    }
    geo.push(fmt_x(geomean(&per_impl["accd_model"])));
    table.row(geo);
    table.print(&format!(
        "Fig. 10: K-means speedup breakdown vs Baseline (scale {scale}; paper avg: 3.77x / 2.63x / 2.69x / 37.37x). \
         Last column projects AccD CPU-FPGA onto the DE10-Pro via the Eq. 5-8 cost model"
    ));
}
