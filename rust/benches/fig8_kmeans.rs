//! Fig. 8a — K-means performance comparison (Baseline / TOP / CBLAS /
//! AccD) across the Table V K-means datasets, speedups normalized to
//! Baseline, exactly the rows the paper's bar chart plots.
//!
//! Scale with ACCD_BENCH_SCALE (default 0.05 of the paper's sizes);
//! the shape of the comparison — who wins, roughly by what factor — is
//! the reproduction target, not absolute runtimes.

use accd::data::tablev;
use accd::figures;
use accd::util::bench::{fmt_x, Table};
use accd::util::geomean;

fn main() {
    let scale = figures::bench_scale();
    let specs = tablev::kmeans_datasets();
    eprintln!("fig8a: K-means sweep at scale {scale} ({} datasets)", specs.len());
    let rows = match figures::fig8_kmeans(scale, &specs) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("fig8a failed (run `make artifacts`?): {e}");
            std::process::exit(1);
        }
    };
    let speedups = figures::speedups(&rows);
    let modeled = figures::modeled_speedups(&rows);
    let mut table =
        Table::new(&["dataset", "TOP", "CBLAS", "AccD (measured)", "AccD (DE10 model)"]);
    let mut per_impl: std::collections::HashMap<&str, Vec<f64>> = Default::default();
    for spec in &specs {
        let get = |set: &[(String, String, f64)], imp: &str| {
            set.iter()
                .find(|(d, i, _)| d == spec.name && i == imp)
                .map(|(_, _, s)| *s)
                .unwrap_or(f64::NAN)
        };
        let (t, c, a) =
            (get(&speedups, "top"), get(&speedups, "cblas"), get(&speedups, "accd"));
        let am = get(&modeled, "accd");
        per_impl.entry("top").or_default().push(t);
        per_impl.entry("cblas").or_default().push(c);
        per_impl.entry("accd").or_default().push(a);
        per_impl.entry("accd_model").or_default().push(am);
        table.row(vec![spec.name.to_string(), fmt_x(t), fmt_x(c), fmt_x(a), fmt_x(am)]);
    }
    table.row(vec![
        "geomean".to_string(),
        fmt_x(geomean(&per_impl["top"])),
        fmt_x(geomean(&per_impl["cblas"])),
        fmt_x(geomean(&per_impl["accd"])),
        fmt_x(geomean(&per_impl["accd_model"])),
    ]);
    table.print(&format!(
        "Fig. 8a: K-means speedup vs Baseline (scale {scale}; paper avg: TOP 9.1x, CBLAS 9.2x, AccD 31.4x). \
         'measured' runs the accelerator on this CPU-PJRT testbed; 'DE10 model' replaces device wall time \
         with the paper's Eq. 5-8 cost model"
    ));
}
