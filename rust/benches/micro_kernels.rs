//! Micro-benchmarks of the hot-path building blocks, used by the perf
//! pass (EXPERIMENTS.md §Perf) to localize bottlenecks:
//!
//! * PJRT distance tile (per metric / d)
//! * fused K-means assignment tile
//! * N-body force tile
//! * CPU-side substrates: sgemm_nt, TopK merge, grouping build
//! * inter-group layout scheduling

use accd::baselines::cblas;
use accd::config::AccdConfig;
use accd::data::synthetic;
use accd::gti::Grouping;
use accd::runtime::Runtime;
use accd::util::bench::Bencher;
use accd::util::rng::Rng;
use accd::util::topk::TopK;

fn main() {
    let b = Bencher::from_env();
    let mut rng = Rng::new(9);

    // --- device tiles ------------------------------------------------------
    match Runtime::load("artifacts") {
        Ok(rt) => {
            let t = rt.manifest().tile.clone();
            for d in [4usize, 16, 64, 128] {
                let a: Vec<f32> = (0..t.m * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                let bb: Vec<f32> = (0..t.n * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                b.run(&format!("pjrt/distance_l2sq/d{d}"), || {
                    rt.distance_tile("l2sq", d, &a, &bb).unwrap()
                });
            }
            let d = 16;
            let a: Vec<f32> = (0..t.m * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let bb: Vec<f32> = (0..t.n * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            b.run("pjrt/distance_l1/d16", || rt.distance_tile("l1", d, &a, &bb).unwrap());
            for k_pad in [64usize, 256, 1024] {
                let c: Vec<f32> = (0..k_pad * d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                b.run(&format!("pjrt/kmeans_assign/k{k_pad}_d{d}"), || {
                    rt.kmeans_assign_tile(k_pad, d, &a, &c).unwrap()
                });
            }
            let bt = t.nbody;
            let pi: Vec<f32> = (0..bt * 3).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let pj: Vec<f32> = (0..bt * 3).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let m: Vec<f32> = (0..bt).map(|_| rng.range_f32(0.1, 1.0)).collect();
            b.run("pjrt/nbody_tile", || {
                rt.nbody_accel_tile_masked(&pi, &pj, &m, 1e-4, 0.5).unwrap()
            });
        }
        Err(e) => eprintln!("skipping device micro-benches: {e}"),
    }

    // --- CPU substrates -----------------------------------------------------
    let m = synthetic::uniform(256, 64, 1).points;
    let n = synthetic::uniform(256, 64, 2).points;
    let mut c = vec![0.0f32; 256 * 256];
    b.run("cpu/sgemm_nt/256x256x64", || {
        cblas::sgemm_nt(m.as_slice(), n.as_slice(), &mut c, 256, 256, 64)
    });

    let vals: Vec<f32> = (0..10_000).map(|_| rng.f32()).collect();
    b.run("cpu/topk_merge/10k_k100", || {
        let mut h = TopK::new(100);
        for (i, &v) in vals.iter().enumerate() {
            h.push(v, i as u32);
        }
        h.into_sorted()
    });

    let ds = synthetic::clustered(20_000, 16, 70, 0.03, 3);
    b.run("cpu/grouping_build/20k_z70", || {
        Grouping::build(&ds.points, 70, 3, 4096, 5).unwrap()
    });

    // --- layout scheduling ---------------------------------------------------
    let cands: Vec<Vec<u32>> = (0..500)
        .map(|_| {
            let mut c: Vec<u32> = (0..64u32).filter(|_| rng.f32() < 0.3).collect();
            c.sort_unstable();
            c
        })
        .collect();
    b.run("cpu/layout_schedule/500grp", || accd::layout::schedule_source_groups(&cands));

    // --- config provenance ----------------------------------------------------
    let cfg = AccdConfig::new();
    b.run("cpu/config_json_roundtrip", || {
        AccdConfig::from_json(&cfg.to_json()).unwrap()
    });
}
