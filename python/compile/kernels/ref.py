"""Pure-jnp correctness oracles for the AccD distance kernels.

These are the L1 reference implementations: every Pallas kernel in this
package must match the corresponding function here (up to float
tolerance) under pytest.  They are also used by aot.py's self-check
before an artifact is written.
"""

import jax
import jax.numpy as jnp


def pairwise_l2sq(a, b):
    """Squared Euclidean distance matrix.

    a: (m, d), b: (n, d)  ->  (m, n) with out[i, j] = ||a_i - b_j||^2.

    Uses the same RSS + matmul decomposition as the paper's Eq. 4 so the
    numerics (including cancellation behaviour) match the Pallas kernel.
    """
    rss_a = jnp.sum(a * a, axis=1, keepdims=True)  # (m, 1)
    rss_b = jnp.sum(b * b, axis=1, keepdims=True).T  # (1, n)
    cross = a @ b.T  # (m, n)
    out = rss_a - 2.0 * cross + rss_b
    # Clamp tiny negative values produced by cancellation: distances are
    # non-negative by definition and downstream sqrt must not NaN.
    return jnp.maximum(out, 0.0)


def pairwise_l2(a, b):
    """Euclidean distance matrix (sqrt of pairwise_l2sq)."""
    return jnp.sqrt(pairwise_l2sq(a, b))


def pairwise_l1(a, b):
    """L1 (Manhattan) distance matrix.

    a: (m, d), b: (n, d)  ->  (m, n) with out[i, j] = sum_k |a_ik - b_jk|.
    """
    return jnp.sum(jnp.abs(a[:, None, :] - b[None, :, :]), axis=-1)


def pairwise_weighted_l2sq(a, b, w):
    """Weighted squared Euclidean distance: sum_k w_k * (a_ik - b_jk)^2.

    Implemented by pre-scaling with sqrt(w) so the matmul decomposition
    still applies; w: (d,).
    """
    sw = jnp.sqrt(w)
    return pairwise_l2sq(a * sw[None, :], b * sw[None, :])


def pairwise_weighted_l1(a, b, w):
    """Weighted L1 distance: sum_k w_k * |a_ik - b_jk|; w: (d,)."""
    return jnp.sum(
        w[None, None, :] * jnp.abs(a[:, None, :] - b[None, :, :]), axis=-1
    )


def rowwise_square_sum(a):
    """Row-wise Square Sum (RSS) from the paper's Fig. 6: (m, d) -> (m,)."""
    return jnp.sum(a * a, axis=1)


def kmeans_assign(points, centers):
    """One K-means assignment step: argmin center + its distance.

    points: (m, d), centers: (k, d) -> (idx: (m,) int32, dist: (m,) f32)
    """
    d = pairwise_l2sq(points, centers)
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    return idx, jnp.min(d, axis=1)


def topk_smallest(dist, k):
    """Top-K smallest values + indices per row of a distance matrix.

    dist: (m, n) -> (vals: (m, k), idx: (m, k) int32), ascending.
    """
    neg_vals, idx = jax.lax.top_k(-dist, k)
    return -neg_vals, idx.astype(jnp.int32)
