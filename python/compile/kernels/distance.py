"""Pallas distance-computation kernels (Layer 1).

This is the AccD "distance computation kernel" of paper §V-B, rethought
for the TPU execution model instead of the paper's OpenCL/FPGA one:

  paper (FPGA / OpenCL)            this kernel (TPU / Pallas)
  -------------------------------  -----------------------------------
  kernel thread workgroup ("red    grid program over (m/bm, n/bn)
  square box" of Fig. 6)           BlockSpec tiles
  on-chip block RAM sharing of     VMEM-resident A/B tiles (BlockSpec
  source/target points             brings each HBM tile in once)
  DSP vector pipelines (SIMD x     MXU systolic matmul for the cross
  unroll factors)                  term of Eq. 4
  RSS pre-compute units            VPU elementwise square + reduce

The paper's Eq. 4 decomposition is kept verbatim:
    (A - B)^2 = A^2 - 2 A.B + B^2
so the dominant O(m*n*d) work runs on the MXU as a (bm, d) x (d, bn)
matmul per tile, and the RSS terms are rank-1 broadcasts.

All kernels are lowered with interpret=True: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness (and
artifact) path; real-TPU performance is estimated analytically in
DESIGN.md from the VMEM footprint + MXU utilisation of these BlockSpecs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile shape. 64x64 output tile with d<=128:
#   A tile 64x128xf32 = 32 KiB, B tile 32 KiB, O tile 16 KiB -> ~80 KiB
# of VMEM, comfortably under the ~16 MiB/core budget, and the cross-term
# matmul (64x128)@(128x64) maps onto full 128-lane MXU passes.
DEFAULT_BM = 64
DEFAULT_BN = 64


def _l2sq_kernel(a_ref, b_ref, o_ref):
    """One (bm, bn) tile of the squared-L2 distance matrix.

    a_ref: (bm, d) VMEM tile of source points
    b_ref: (bn, d) VMEM tile of target points
    o_ref: (bm, bn) output tile
    """
    a = a_ref[...]
    b = b_ref[...]
    # Eq. 4: A^2 - 2 A.B + B^2.  The matmul is the MXU hot spot; always
    # accumulate in f32 regardless of input dtype.
    cross = jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    rss_a = jnp.sum(a * a, axis=1, keepdims=True)  # (bm, 1)
    rss_b = jnp.sum(b * b, axis=1, keepdims=True).T  # (1, bn)
    o_ref[...] = jnp.maximum(rss_a - 2.0 * cross + rss_b, 0.0)


def _l1_kernel(a_ref, b_ref, o_ref):
    """One (bm, bn) tile of the L1 distance matrix.

    No matmul decomposition exists for L1, so this is a VPU kernel: the
    (bm, bn, d) broadcast lives in registers/VMEM per tile.
    """
    a = a_ref[...]
    b = b_ref[...]
    o_ref[...] = jnp.sum(jnp.abs(a[:, None, :] - b[None, :, :]), axis=-1)


def pairwise_distance(a, b, *, metric="l2sq", bm=DEFAULT_BM, bn=DEFAULT_BN):
    """Tiled pairwise distance via pallas_call.

    a: (m, d), b: (n, d) with m % bm == 0 and n % bn == 0 (the rust
    runtime pads tiles to these multiples before dispatch).
    Returns the (m, n) distance matrix.
    """
    m, d = a.shape
    n, _ = b.shape
    if m % bm or n % bn:
        raise ValueError(f"tile shapes must divide inputs: m={m} bm={bm} n={n} bn={bn}")
    kernel = {"l2sq": _l2sq_kernel, "l1": _l1_kernel}[metric]
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Source tile marches down the grid's first axis only: each
            # (bm, d) strip is re-used across all n/bn target tiles —
            # the Pallas analogue of the paper's workgroup point-sharing.
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def pairwise_weighted(a, b, w, *, metric="l2sq", bm=DEFAULT_BM, bn=DEFAULT_BN):
    """Weighted-metric variant (paper Table I `Weg mat`).

    For L2 the weight folds into a sqrt(w) pre-scale so the MXU kernel is
    reused unchanged; for L1 the weight is applied inside a dedicated
    kernel closure.
    """
    if metric == "l2sq":
        sw = jnp.sqrt(w)
        return pairwise_distance(a * sw[None, :], b * sw[None, :], metric="l2sq", bm=bm, bn=bn)

    def _wl1_kernel(a_ref, b_ref, w_ref, o_ref):
        aa = a_ref[...]
        bb = b_ref[...]
        ww = w_ref[...]
        o_ref[...] = jnp.sum(
            ww[None, None, :] * jnp.abs(aa[:, None, :] - bb[None, :, :]), axis=-1
        )

    m, d = a.shape
    n, _ = b.shape
    if m % bm or n % bn:
        raise ValueError("tile shapes must divide inputs")
    return pl.pallas_call(
        _wl1_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b, w)


@functools.partial(jax.jit, static_argnames=("bm",))
def rss(a, *, bm=DEFAULT_BM):
    """Standalone Row-wise Square Sum kernel (paper Fig. 6 pre-compute).

    Exposed separately so the rust coordinator can amortise RSS of a
    static target set across many source batches.
    """
    m, d = a.shape

    def _rss_kernel(a_ref, o_ref):
        aa = a_ref[...]
        o_ref[...] = jnp.sum(aa * aa, axis=1)

    return pl.pallas_call(
        _rss_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(a)
