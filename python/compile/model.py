"""Layer-2 JAX compute graphs for AccD (build-time only).

Each public function here is a jittable graph that the AOT pipeline
(aot.py) lowers to one HLO-text artifact per concrete shape.  The rust
coordinator (rust/src/runtime) loads these artifacts through PJRT and
calls them from the hot path — python never runs at request time.

Graphs provided (all shapes static; the rust side pads tiles):

  distance_tile        (bm, d) x (bn, d)        -> (bm, bn)     the hot tile
  distance_tile_l1     same, L1 metric
  kmeans_assign_tile   (bm, d) x (k, d)         -> idx, dist    fused assign
  distance_topk_tile   (bm, d) x (bn, d)        -> vals, idx    fused KNN tile
  nbody_accel_tile     (bm, 3) x (bn, 3) x mass -> (bm, 3)      force accum

The distance tiles call the Pallas kernel (kernels/distance.py) so the
L1 kernel lowers into the same HLO module.
"""

import jax
import jax.numpy as jnp

from .kernels import distance as K


def distance_tile(a, b):
    """Squared-L2 distance tile — the paper's Eq. 4 kernel (Fig. 6)."""
    return (K.pairwise_distance(a, b, metric="l2sq", bm=a.shape[0], bn=b.shape[0]),)


def distance_tile_l1(a, b):
    """L1 distance tile (paper Table I: Unweighted L1 metric)."""
    return (K.pairwise_distance(a, b, metric="l1", bm=a.shape[0], bn=b.shape[0]),)


def distance_tile_weighted(a, b, w):
    """Weighted-L2sq distance tile (paper Table I: weighted metric)."""
    return (K.pairwise_weighted(a, b, w, metric="l2sq", bm=a.shape[0], bn=b.shape[0]),)


def kmeans_assign_tile(points, centers):
    """Fused distance + argmin tile for K-means assignment.

    Keeps the (bm, k) distance matrix on-device and returns only the
    assignment index and its distance — the (bm*k -> bm) transfer saving
    the paper gets from running Dist_Select on the FPGA side.
    """
    dmat = K.pairwise_distance(
        points, centers, metric="l2sq", bm=points.shape[0], bn=centers.shape[0]
    )
    idx = jnp.argmin(dmat, axis=1).astype(jnp.int32)
    best = jnp.min(dmat, axis=1)
    return idx, best


def distance_topk_tile(a, b, k):
    """Fused distance + Top-K selection tile for KNN-join.

    Computes the (bm, bn) tile then reduces to the per-source-point
    Top-K candidates within this tile; the rust side merges tiles.

    NOTE: deliberately lowered through `sort` rather than
    `jax.lax.top_k` — the latter emits a `topk(..., largest=true)` HLO
    instruction that xla_extension 0.5.1's text parser rejects, while
    variadic `sort` round-trips fine (see aot_recipe notes).
    """
    dmat = K.pairwise_distance(a, b, metric="l2sq", bm=a.shape[0], bn=b.shape[0])
    k = min(k, b.shape[0])
    iota = jax.lax.broadcasted_iota(jnp.int32, dmat.shape, 1)
    vals_sorted, idx_sorted = jax.lax.sort((dmat, iota), dimension=1, num_keys=1)
    return vals_sorted[:, :k], idx_sorted[:, :k]


def nbody_accel_tile(pos_i, pos_j, mass_j, params):
    """Radius-limited gravitational acceleration tile.

    pos_i: (bm, 3), pos_j: (bn, 3), mass_j: (bn,),
    params: (2,) = [eps2 softening, rmax2 interaction-radius^2].

    Only neighbors within sqrt(rmax2) contribute (the paper's N-body
    benchmark computes forces for particles "within a radius R");
    padding rows carry mass 0 and therefore contribute nothing.
    Returns (bm, 3) acceleration contribution — fused with the distance
    tile so the distance matrix never leaves the device.
    """
    eps2, rmax2 = params[0], params[1]
    d = pos_i[:, None, :] - pos_j[None, :, :]  # (bm, bn, 3)
    r2 = jnp.sum(d * d, axis=-1)  # (bm, bn)
    in_range = (r2 <= rmax2).astype(jnp.float32)
    r2s = r2 + eps2
    inv_r3 = jax.lax.rsqrt(r2s) / r2s  # 1 / r^3
    w = mass_j[None, :] * inv_r3 * in_range
    acc = -jnp.sum(d * w[..., None], axis=1)
    return (acc,)


def rss_tile(a):
    """Standalone Row-wise Square Sum (paper Fig. 6 pre-compute stage)."""
    return (K.rss(a, bm=a.shape[0]),)
