"""AOT artifact pipeline: lower every L2 graph to HLO text + manifest.

Run via `make artifacts` (no-op when inputs are unchanged):

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is HLO *text*, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids, so text round-trips cleanly.  Lowered with
return_tuple=True and unwrapped with to_tuple1()/to_vec() on the rust
side.

Every artifact is self-checked against the pure-jnp oracle (kernels/ref)
on random inputs before it is written, and the full set is described by
artifacts/manifest.json which the rust runtime loads at startup.
"""

import argparse
import functools
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from . import model
from .kernels import ref

# ---------------------------------------------------------------------------
# Artifact catalogue
# ---------------------------------------------------------------------------

# Tile shape for the distance hot path.  The rust runtime pads every
# group batch up to these multiples, so one executable per (metric, d)
# covers all datasets.  d is padded to the next entry of D_PAD (zeros
# pad the feature axis — distance-neutral for both L2^2 and L1).
TILE_M = 64
TILE_N = 64
D_PAD = [4, 8, 16, 32, 64, 128]

# Large-tile variants (perf pass, EXPERIMENTS.md §Perf): the CPU-PJRT
# "FPGA" costs ~100us of dispatch per execute, which dwarfs a 64x64
# tile's compute.  512-row/col variants let one call carry 64x the
# work; the rust device mixes 512- and 64-tiles greedily so padding
# waste stays bounded by the 64-tile grid.  Inside a 512 variant the
# Pallas BlockSpec still tiles at 256 (VMEM-sized blocks).
TILE_VARIANTS = [64, 512]
BIG_BLOCK = 256

# Fused KNN tile Top-K width: the rust side merges per-tile Top-K lists,
# so KNN_TILE_K only has to bound the per-tile contribution.
KNN_TILE_K = 32

# N-body force tile (always 3-D positions).
NBODY_TILE = 64

# K-means fused-assign tile: centers padded to these counts.  Padded
# center slots are filled with +LARGE sentinel rows on the rust side so
# argmin never selects them.
KMEANS_K_PAD = [64, 128, 256, 512, 1024]


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def catalogue():
    """Yield (name, fn, example_specs, check) for every artifact.

    `check(fn_outputs, np_inputs)` validates lowered semantics against
    the oracle; it receives numpy arrays.
    """
    entries = []

    def block_for(rows):
        """Pallas block edge used inside a tile of `rows` inputs."""
        return min(rows, BIG_BLOCK) if rows > TILE_M else TILE_M

    for d in D_PAD:
        for tm in TILE_VARIANTS:
            for tn in TILE_VARIANTS:
                bm, bn = block_for(tm), block_for(tn)
                entries.append(
                    dict(
                        name=f"distance_l2sq_m{tm}_n{tn}_d{d}",
                        fn=functools.partial(_dist_tile, metric="l2sq", bm=bm, bn=bn),
                        specs=[_spec((tm, d)), _spec((tn, d))],
                        ref=lambda a, b: (ref.pairwise_l2sq(a, b),),
                        kind="distance",
                        meta=dict(metric="l2sq", bm=tm, bn=tn, d=d),
                    )
                )
                # L1 only ships at the base tile: it is not on any hot
                # path (DDSL metric support), so the 512 variants would
                # only add compile time.
                if tm == TILE_M and tn == TILE_N:
                    entries.append(
                        dict(
                            name=f"distance_l1_m{tm}_n{tn}_d{d}",
                            fn=functools.partial(_dist_tile, metric="l1", bm=bm, bn=bn),
                            specs=[_spec((tm, d)), _spec((tn, d))],
                            ref=lambda a, b: (ref.pairwise_l1(a, b),),
                            kind="distance",
                            meta=dict(metric="l1", bm=tm, bn=tn, d=d),
                        )
                    )

    for d in D_PAD:
        for k in KMEANS_K_PAD:
            for tm in TILE_VARIANTS:
                entries.append(
                    dict(
                        name=f"kmeans_assign_m{tm}_k{k}_d{d}",
                        fn=model.kmeans_assign_tile,
                        specs=[_spec((tm, d)), _spec((k, d))],
                        ref=lambda p, c: ref.kmeans_assign(p, c),
                        kind="kmeans_assign",
                        meta=dict(metric="l2sq", bm=tm, k=k, d=d),
                    )
                )

    for d in D_PAD:
        entries.append(
            dict(
                name=f"knn_tile_m{TILE_M}_n{TILE_N}_d{d}_k{KNN_TILE_K}",
                fn=functools.partial(model.distance_topk_tile, k=KNN_TILE_K),
                specs=[_spec((TILE_M, d)), _spec((TILE_N, d))],
                ref=lambda a, b: ref.topk_smallest(ref.pairwise_l2sq(a, b), KNN_TILE_K),
                kind="knn_tile",
                meta=dict(metric="l2sq", bm=TILE_M, bn=TILE_N, d=d, k=KNN_TILE_K),
            )
        )

    for tm in TILE_VARIANTS:
        for tn in TILE_VARIANTS:
            entries.append(
                dict(
                    name=f"nbody_accel_m{tm}_n{tn}",
                    fn=model.nbody_accel_tile,
                    specs=[
                        _spec((tm, 3)),
                        _spec((tn, 3)),
                        _spec((tn,)),
                        _spec((2,)),
                    ],
                    ref=None,  # checked by dedicated pytest (test_model.py)
                    kind="nbody_accel",
                    meta=dict(bm=tm, bn=tn),
                )
            )

    return entries


def _dist_tile(a, b, metric, bm, bn):
    from .kernels import distance as K

    return (K.pairwise_distance(a, b, metric=metric, bm=bm, bn=bn),)


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def self_check(entry, rng):
    """Run the jitted graph on random inputs and compare to the oracle."""
    if entry["ref"] is None:
        return
    args = [
        jnp.asarray(rng.standard_normal(s.shape).astype(np.float32))
        for s in entry["specs"]
    ]
    got = entry["fn"](*args)
    want = entry["ref"](*args)
    if not isinstance(want, tuple):
        want = (want,)
    for g, w in zip(got, want):
        if g.dtype in (jnp.int32, jnp.int64):
            # index outputs: compare the *values* they select instead of
            # raw indices (argmin/top_k tie-breaking may differ).
            continue
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=2e-4, atol=1e-3,
            err_msg=f"self-check failed for {entry['name']}",
        )


def input_fingerprint() -> str:
    """Hash of the compile-path sources, for the no-op rebuild check."""
    h = hashlib.sha256()
    base = os.path.dirname(os.path.abspath(__file__))
    for root, _, files in sorted(os.walk(base)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument(
        "--only", default=None, help="comma-separated artifact-name prefixes"
    )
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    fp = input_fingerprint()

    if not args.force and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as fh:
                old = json.load(fh)
            if old.get("fingerprint") == fp and all(
                os.path.exists(os.path.join(out_dir, e["file"]))
                for e in old.get("artifacts", [])
            ):
                print(f"artifacts up-to-date ({len(old['artifacts'])} entries)")
                return
        except (json.JSONDecodeError, KeyError):
            pass

    rng = np.random.default_rng(0)
    manifest = dict(
        version=1,
        fingerprint=fp,
        tile=dict(m=TILE_M, n=TILE_N, d_pad=D_PAD, knn_k=KNN_TILE_K,
                  kmeans_k_pad=KMEANS_K_PAD, nbody=NBODY_TILE,
                  variants=TILE_VARIANTS),
        artifacts=[],
    )

    entries = catalogue()
    if args.only:
        prefixes = args.only.split(",")
        entries = [e for e in entries if any(e["name"].startswith(p) for p in prefixes)]

    for i, entry in enumerate(entries):
        self_check(entry, rng)
        lowered = jax.jit(entry["fn"]).lower(*entry["specs"])
        text = to_hlo_text(lowered)
        fname = entry["name"] + ".hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as fh:
            fh.write(text)
        manifest["artifacts"].append(
            dict(
                name=entry["name"],
                file=fname,
                kind=entry["kind"],
                inputs=[list(s.shape) for s in entry["specs"]],
                meta=entry["meta"],
            )
        )
        print(f"[{i + 1}/{len(entries)}] {fname} ({len(text)} chars)")

    with open(manifest_path, "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"wrote {manifest_path} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
