"""AOT pipeline tests: fingerprint no-op, manifest schema, catalogue."""

import json
import os
import subprocess
import sys

from compile import aot


def test_fingerprint_is_stable_and_source_sensitive():
    fp1 = aot.input_fingerprint()
    fp2 = aot.input_fingerprint()
    assert fp1 == fp2 and len(fp1) == 64


def test_catalogue_tile_variants_present():
    names = {e["name"] for e in aot.catalogue()}
    for tv in aot.TILE_VARIANTS:
        assert f"kmeans_assign_m{tv}_k64_d16" in names
        assert f"nbody_accel_m{tv}_n{tv}" in names
    # L1 ships only at the base tile (not on a hot path).
    assert "distance_l1_m64_n64_d16" in names
    assert "distance_l1_m512_n512_d16" not in names


def test_manifest_matches_catalogue(tmp_path=None):
    manifest_path = os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json"
    )
    if not os.path.exists(manifest_path):
        import pytest

        pytest.skip("run `make artifacts` first")
    with open(manifest_path) as fh:
        m = json.load(fh)
    assert m["version"] == 1
    assert m["tile"]["variants"] == aot.TILE_VARIANTS
    names = {e["name"] for e in m["artifacts"]}
    expected = {e["name"] for e in aot.catalogue()}
    assert names == expected
    # Every referenced file exists and is non-trivial HLO text.
    art_dir = os.path.dirname(manifest_path)
    for e in m["artifacts"]:
        p = os.path.join(art_dir, e["file"])
        assert os.path.getsize(p) > 200, e["file"]
        with open(p) as fh:
            head = fh.read(4096)
        assert "ENTRY" in head or "HloModule" in head


def test_aot_noop_when_up_to_date():
    """Second invocation must detect the fingerprint and skip."""
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.exists(os.path.join(art_dir, "manifest.json")):
        import pytest

        pytest.skip("run `make artifacts` first")
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", art_dir],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert out.returncode == 0, out.stderr
    assert "up-to-date" in out.stdout
