"""L2 model-graph correctness: shapes + semantics of every AOT graph."""

import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def rand(shape, seed, scale=2.0):
    return jnp.asarray(
        np.random.default_rng(seed).uniform(-scale, scale, size=shape).astype(np.float32)
    )


def test_distance_tile_tuple_contract():
    a, b = rand((64, 16), 1), rand((64, 16), 2)
    out = model.distance_tile(a, b)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (64, 64)


def test_kmeans_assign_tile_semantics():
    pts, ctr = rand((64, 8), 3), rand((32, 8), 4)
    idx, dist = model.kmeans_assign_tile(pts, ctr)
    want_idx, want_dist = ref.kmeans_assign(pts, ctr)
    # Indices may differ on exact ties; distances must match.
    npt.assert_allclose(dist, want_dist, rtol=2e-4, atol=1e-3)
    # Index consistency: distance at idx equals the min distance.
    dmat = ref.pairwise_l2sq(pts, ctr)
    at = jnp.take_along_axis(dmat, idx[:, None].astype(jnp.int32), axis=1)[:, 0]
    npt.assert_allclose(at, want_dist, rtol=2e-4, atol=1e-3)
    assert idx.dtype == jnp.int32


def test_kmeans_assign_avoids_sentinel_rows():
    pts = rand((64, 8), 5)
    ctr = np.array(rand((32, 8), 6), copy=True)
    ctr[20:, 0] = 1.0e15  # sentinel padding rows
    idx, _ = model.kmeans_assign_tile(pts, jnp.asarray(ctr))
    assert int(jnp.max(idx)) < 20


def test_distance_topk_tile_sorted_and_consistent():
    a, b = rand((64, 16), 7), rand((64, 16), 8)
    vals, idx = model.distance_topk_tile(a, b, k=32)
    assert vals.shape == (64, 32)
    assert idx.dtype == jnp.int32
    dmat = np.asarray(ref.pairwise_l2sq(a, b))
    v = np.asarray(vals)
    assert (np.diff(v, axis=1) >= -1e-5).all(), "per-row values not ascending"
    for r in range(64):
        want = np.sort(dmat[r])[:32]
        npt.assert_allclose(v[r], want, rtol=2e-4, atol=1e-3)
        npt.assert_allclose(dmat[r, np.asarray(idx)[r]], v[r], rtol=2e-4, atol=1e-3)


def test_nbody_accel_tile_matches_direct_sum():
    pos_i, pos_j = rand((64, 3), 9, 1.0), rand((64, 3), 10, 1.0)
    mass = jnp.abs(rand((64,), 11, 1.0)) + 0.1
    eps2, rmax2 = 1e-4, 0.7
    (acc,) = model.nbody_accel_tile(pos_i, pos_j, mass, jnp.array([eps2, rmax2]))
    pi, pj, m = map(np.asarray, (pos_i, pos_j, mass))
    want = np.zeros((64, 3), dtype=np.float64)
    for i in range(64):
        d = pi[i] - pj  # (64, 3)
        r2 = (d * d).sum(axis=1)
        mask = r2 <= rmax2
        r2s = r2 + eps2
        w = m * mask / (np.sqrt(r2s) * r2s)
        want[i] = -(d * w[:, None]).sum(axis=0)
    npt.assert_allclose(np.asarray(acc), want, rtol=1e-3, atol=1e-3)


def test_nbody_zero_mass_rows_are_inert():
    pos_i, pos_j = rand((64, 3), 12, 1.0), rand((64, 3), 13, 1.0)
    mass = np.array(jnp.abs(rand((64,), 14, 1.0)) + 0.1, copy=True)
    mass[32:] = 0.0
    params = jnp.array([1e-4, 10.0])
    (a1,) = model.nbody_accel_tile(pos_i, pos_j, jnp.asarray(mass), params)
    pos_j2 = np.array(pos_j, copy=True)
    pos_j2[32:] += 7.0  # move the zero-mass rows far away
    (a2,) = model.nbody_accel_tile(pos_i, jnp.asarray(pos_j2), jnp.asarray(mass), params)
    npt.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(k=st.sampled_from([1, 4, 16, 64]), seed=st.integers(0, 2**31 - 1))
def test_topk_tile_k_sweep(k, seed):
    a, b = rand((16, 4), seed), rand((64, 4), seed + 1)
    # bm=16 tile, bn=64: use pairwise over custom tile shape via model
    vals, idx = model.distance_topk_tile(
        jnp.pad(a, ((0, 48), (0, 0))), b, k=k
    )
    dmat = np.asarray(ref.pairwise_l2sq(a, b))
    v = np.asarray(vals)[:16]
    for r in range(16):
        want = np.sort(dmat[r])[: min(k, 64)]
        npt.assert_allclose(v[r][: len(want)], want, rtol=5e-4, atol=2e-3)


def test_aot_catalogue_is_complete_and_self_checking():
    """The AOT catalogue covers every (metric, d) the manifest promises
    and every entry passes its oracle self-check."""
    from compile import aot

    entries = aot.catalogue()
    names = {e["name"] for e in entries}
    for d in aot.D_PAD:
        assert f"distance_l2sq_m{aot.TILE_M}_n{aot.TILE_N}_d{d}" in names
        assert f"distance_l1_m{aot.TILE_M}_n{aot.TILE_N}_d{d}" in names
        for k in aot.KMEANS_K_PAD:
            assert f"kmeans_assign_m{aot.TILE_M}_k{k}_d{d}" in names
    rng = np.random.default_rng(0)
    # Self-check a representative subset (full set runs in `make artifacts`).
    for e in entries[:4]:
        aot.self_check(e, rng)


def test_hlo_text_lowering_produces_parseable_module():
    """Lowered HLO text must use the old parser's vocabulary: in
    particular no `topk(...)` instruction (xla_extension 0.5.1 rejects
    it — the reason distance_topk_tile lowers through sort)."""
    import jax

    from compile import aot

    spec = jax.ShapeDtypeStruct((64, 8), jnp.float32)
    lowered = jax.jit(lambda a, b: model.distance_topk_tile(a, b, k=32)).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert " topk(" not in text, "jax.lax.top_k leaked into the HLO"
    assert "sort(" in text
