"""Kernel-vs-reference correctness: the CORE L1 signal.

Every Pallas kernel must match the pure-jnp oracle in kernels/ref.py.
Hypothesis sweeps shapes and dtypes; fixed cases pin the tile shapes the
AOT catalogue actually ships.
"""

import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import distance as K
from compile.kernels import ref


def rand(shape, seed, scale=2.0):
    return jnp.asarray(
        np.random.default_rng(seed).uniform(-scale, scale, size=shape).astype(np.float32)
    )


# ---------------------------------------------------------------------------
# Fixed tile shapes (the shipped artifact geometry)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d", [4, 8, 16, 32, 64, 128])
def test_l2sq_matches_ref_at_artifact_dims(d):
    a, b = rand((64, d), 1), rand((64, d), 2)
    npt.assert_allclose(
        K.pairwise_distance(a, b, metric="l2sq"),
        ref.pairwise_l2sq(a, b),
        rtol=2e-4,
        atol=1e-3,
    )


@pytest.mark.parametrize("d", [4, 16, 128])
def test_l1_matches_ref_at_artifact_dims(d):
    a, b = rand((64, d), 3), rand((64, d), 4)
    npt.assert_allclose(
        K.pairwise_distance(a, b, metric="l1"),
        ref.pairwise_l1(a, b),
        rtol=2e-4,
        atol=1e-3,
    )


def test_weighted_l2sq_and_l1_match_ref():
    d = 16
    a, b = rand((64, d), 5), rand((64, d), 6)
    w = jnp.abs(rand((d,), 7, scale=1.0)) + 0.01
    npt.assert_allclose(
        K.pairwise_weighted(a, b, w, metric="l2sq"),
        ref.pairwise_weighted_l2sq(a, b, w),
        rtol=5e-4,
        atol=2e-3,
    )
    npt.assert_allclose(
        K.pairwise_weighted(a, b, w, metric="l1"),
        ref.pairwise_weighted_l1(a, b, w),
        rtol=2e-4,
        atol=1e-3,
    )


def test_rss_matches_ref():
    a = rand((128, 32), 8)
    npt.assert_allclose(K.rss(a), ref.rowwise_square_sum(a), rtol=1e-5, atol=1e-5)


def test_distances_nonnegative_and_self_zero():
    a = rand((64, 16), 9)
    d = K.pairwise_distance(a, a, metric="l2sq")
    assert float(jnp.min(d)) >= 0.0
    npt.assert_allclose(jnp.diagonal(d), jnp.zeros(64), atol=1e-3)


def test_tile_shape_must_divide():
    a, b = rand((60, 8), 10), rand((64, 8), 11)
    with pytest.raises(ValueError):
        K.pairwise_distance(a, b, metric="l2sq")


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes, grids, dtypes
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    mt=st.integers(1, 3),
    nt=st.integers(1, 3),
    d=st.sampled_from([1, 2, 3, 5, 8, 17, 33]),
    seed=st.integers(0, 2**31 - 1),
    metric=st.sampled_from(["l2sq", "l1"]),
)
def test_tiled_grid_matches_ref(mt, nt, d, seed, metric):
    """Multi-tile grids (m, n > one tile) agree with the oracle."""
    bm = bn = 16  # small tiles keep interpret-mode runtime in check
    a, b = rand((mt * bm, d), seed), rand((nt * bn, d), seed + 1)
    got = K.pairwise_distance(a, b, metric=metric, bm=bm, bn=bn)
    want = ref.pairwise_l2sq(a, b) if metric == "l2sq" else ref.pairwise_l1(a, b)
    npt.assert_allclose(got, want, rtol=5e-4, atol=2e-3)


@settings(max_examples=15, deadline=None)
@given(
    d=st.sampled_from([2, 4, 9, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_zero_feature_padding_neutral(d, seed):
    """Zero-padding the feature axis never changes distances."""
    a, b = rand((16, d), seed), rand((16, d), seed + 1)
    pad = 3
    ap = jnp.pad(a, ((0, 0), (0, pad)))
    bp = jnp.pad(b, ((0, 0), (0, pad)))
    npt.assert_allclose(
        K.pairwise_distance(ap, bp, metric="l2sq", bm=16, bn=16),
        K.pairwise_distance(a, b, metric="l2sq", bm=16, bn=16),
        rtol=1e-5,
        atol=1e-4,
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([1e-3, 1.0, 1e3]))
def test_l2sq_stable_across_magnitudes(seed, scale):
    """Eq. 4 decomposition stays accurate across value magnitudes
    (catastrophic cancellation is clamped, never negative)."""
    a, b = rand((16, 8), seed, scale), rand((16, 8), seed + 1, scale)
    got = np.asarray(K.pairwise_distance(a, b, metric="l2sq", bm=16, bn=16))
    assert (got >= 0.0).all()
    want = np.asarray(ref.pairwise_l2sq(a, b))
    npt.assert_allclose(got, want, rtol=1e-3, atol=1e-3 * scale * scale)
