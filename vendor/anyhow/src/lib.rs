//! Minimal in-tree substitute for the `anyhow` crate.
//!
//! The offline vendored registry does not carry `anyhow`, so this path
//! dependency provides the tiny surface the launcher and the examples
//! actually use: [`Error`], [`Result`], [`anyhow!`], [`bail!`] and
//! [`ensure!`].  Semantics match upstream for that surface: any
//! `std::error::Error + Send + Sync + 'static` converts into [`Error`]
//! via `?`, and `fn main() -> anyhow::Result<()>` prints the message on
//! failure.

/// A type-erased error: the formatted message of whatever was thrown.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: std::fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string() }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

// `fn main() -> Result<(), E>` prints `E` via Debug; format it like
// Display so CLI failures stay readable (upstream anyhow does the same).
impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with the erased error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_and_conversions() {
        fn fails() -> crate::Result<()> {
            crate::ensure!(1 + 1 == 3, "math broke: {}", 42);
            Ok(())
        }
        let err = fails().unwrap_err();
        assert_eq!(err.to_string(), "math broke: 42");

        fn io_bubbles() -> crate::Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io_bubbles().is_err());

        let e: crate::Error = crate::anyhow!("plain {}", "msg");
        assert_eq!(format!("{e:?}"), "plain msg");
    }
}
