#!/usr/bin/env python3
"""Gate serving-bench regressions against a committed baseline.

Usage:
    python3 scripts/check_bench_regression.py BENCH_serve.json BENCH_serve.baseline.json

Compares the scenario rows emitted by `cargo bench --bench
serve_throughput` (see rust/benches/serve_throughput.rs) against the
committed baseline and exits non-zero on a regression.  Only
machine-portable metrics are guarded; raw wall seconds and q/s vary
with the host and are reported, never judged.

Guarded per scenario (tolerance: >20% worse than baseline fails):

* ``speedup_vs_sequential`` — normalized by the SAME run's sequential
  engine calls, so host speed divides out.  Fails when it drops more
  than the tolerance below baseline.
* ``latency_p99_ms`` — only for the ``*openloop*`` scenarios: those
  run arrivals and deadlines on a virtual clock, so the p99 is a
  deterministic property of the schedule, not the host.  Fails when it
  rises more than the tolerance above baseline.
* ``prune_rate`` — K-means scenarios only: the fraction of
  point-iterations the incremental TI bounds answered without device
  work.  Deterministic (seeded data, exact bound algebra).  Fails when
  it drops more than the tolerance below baseline.

Hard invariants (any run, no baseline needed):

* ``flush_failures`` must be 0 everywhere — every scenario runs
  against a healthy engine.
* ``shed`` must be 0 everywhere EXCEPT scenarios with ``overload`` in
  the name, which deliberately offer more than ``queue_cap`` under the
  ``reject`` policy and must report ``shed`` > 0 — a zero there means
  the backpressure path silently stopped rejecting.
* every ``kmeans*`` scenario must report ``prune_rate`` > 0 — later
  iterations of a repeated cohort must prune SOMETHING, or the
  incremental TI path has silently died.
* every ``rangejoin*`` scenario must report ``prune_rate`` > 0 — the
  group-level bounds must prove some group pairs outside the radius,
  or threshold pruning has silently died.
* ``predicted_sheds`` must be 0 everywhere EXCEPT scenarios with
  ``predictive`` in the name (the only rows that enable
  ``serve.predictive_shed``), which must report ``predicted_sheds``
  > 0 under their deliberate saturation.
* paired diurnal rows from the SAME run: the ``*_predictive_*`` row
  must not report more ``deadline_misses`` than its ``*_reactive_*``
  twin — predictive early shedding exists to convert certain misses
  into cheap rejections, never to create new misses.

A baseline value of ``null`` is record-only: the metric is printed but
not judged for that scenario (used for host-dependent values in an
otherwise armed baseline).  A baseline marked ``"bootstrap": true``
(or with no scenarios) records nothing to compare against: the script
prints the measured values and passes, so the first CI run after
adding a scenario is green.  Refresh the baseline from a trusted run
with:

    ACCD_BENCH_FAST=1 cargo bench --bench serve_throughput
    cp BENCH_serve.json BENCH_serve.baseline.json

(keep fast mode consistent: CI smoke runs compare fast-mode numbers;
re-null any value you want to leave unguarded).
"""

import json
import sys

TOLERANCE = 0.20


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"FAIL: cannot read {path}: {e}")
        sys.exit(1)


def rows_by_name(doc):
    return {row["name"]: row for row in doc.get("scenarios", [])}


def metric(row, key):
    """Numeric metric or None (absent or null = record-only)."""
    value = row.get(key)
    return value if isinstance(value, (int, float)) else None


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        sys.exit(2)
    current_path, baseline_path = sys.argv[1], sys.argv[2]
    current = load(current_path)
    baseline = load(baseline_path)
    cur_rows = rows_by_name(current)
    base_rows = rows_by_name(baseline)
    failures = []
    notes = []

    # Hard invariants on the current run.
    for name, row in sorted(cur_rows.items()):
        if row.get("flush_failures", 0):
            failures.append(
                f"{name}: flush_failures = {row['flush_failures']:g} (must be 0)")
        shed = row.get("shed", 0)
        if "overload" in name:
            if not shed:
                failures.append(
                    f"{name}: shed = 0 (overload scenario must shed — the "
                    "reject backpressure path produced nothing)")
        elif shed:
            failures.append(f"{name}: shed = {shed:g} (must be 0)")
        if "kmeans" in name:
            prune = metric(row, "prune_rate")
            if not prune or prune <= 0:
                failures.append(
                    f"{name}: prune_rate = {prune} (must be > 0 — incremental "
                    "TI pruning produced nothing after iteration 1)")
        if "rangejoin" in name:
            prune = metric(row, "prune_rate")
            if not prune or prune <= 0:
                failures.append(
                    f"{name}: prune_rate = {prune} (must be > 0 — group-level "
                    "threshold pruning produced nothing)")
        psheds = row.get("predicted_sheds", 0)
        if "predictive" in name:
            if not psheds:
                failures.append(
                    f"{name}: predicted_sheds = 0 (saturated predictive "
                    "scenario must shed — early deadline shedding produced "
                    "nothing)")
        elif psheds:
            failures.append(
                f"{name}: predicted_sheds = {psheds:g} (must be 0 — "
                "predictive_shed is off for this scenario)")

    # Paired same-run rule: predictive shedding must never cost misses
    # relative to its reactive twin (identical trace, same run).
    for name, row in sorted(cur_rows.items()):
        if "_predictive_" not in name:
            continue
        twin = cur_rows.get(name.replace("_predictive_", "_reactive_"))
        if twin is None:
            continue
        pred_miss = row.get("deadline_misses", 0)
        react_miss = twin.get("deadline_misses", 0)
        if pred_miss > react_miss:
            failures.append(
                f"{name}: deadline_misses {pred_miss:g} exceeds the reactive "
                f"twin's {react_miss:g} — predictive shedding created misses "
                "instead of absorbing them")

    print(f"{current_path}: {len(cur_rows)} scenario(s), "
          f"fast_mode={current.get('fast_mode')}")
    for name, row in sorted(cur_rows.items()):
        extra = ""
        if metric(row, "prune_rate") is not None:
            extra = f", prune_rate {row['prune_rate']:.3f}"
        print(f"  {name}: speedup {row.get('speedup_vs_sequential', 0):.2f}x, "
              f"qps {row.get('qps', 0):.1f}, p99 {row.get('latency_p99_ms', 0):.3f} ms, "
              f"shed {row.get('shed', 0):g}, "
              f"flush_failures {row.get('flush_failures', 0):g}{extra}")

    bootstrap = bool(baseline.get("bootstrap")) or not base_rows
    if bootstrap:
        print(f"\n{baseline_path} is a bootstrap baseline — nothing to compare "
              "against; measured values recorded above.  Refresh it from a "
              "trusted run to arm the gate (see this script's docstring).")
    else:
        if baseline.get("fast_mode") != current.get("fast_mode"):
            notes.append("fast_mode differs from baseline — comparison is "
                         "apples-to-oranges; refresh the baseline in the "
                         "mode CI runs")
        for name, base in sorted(base_rows.items()):
            cur = cur_rows.get(name)
            if cur is None:
                failures.append(f"{name}: scenario present in baseline but "
                                "missing from the current run")
                continue
            base_speedup = metric(base, "speedup_vs_sequential")
            cur_speedup = cur.get("speedup_vs_sequential", 0.0)
            if (base_speedup is not None and base_speedup > 0
                    and cur_speedup < base_speedup * (1 - TOLERANCE)):
                failures.append(
                    f"{name}: speedup_vs_sequential {cur_speedup:.2f}x is "
                    f">{TOLERANCE:.0%} below baseline {base_speedup:.2f}x")
            if "openloop" in name:
                base_p99 = metric(base, "latency_p99_ms")
                cur_p99 = cur.get("latency_p99_ms", 0.0)
                if (base_p99 is not None and base_p99 > 0
                        and cur_p99 > base_p99 * (1 + TOLERANCE)):
                    failures.append(
                        f"{name}: latency_p99_ms {cur_p99:.3f} is "
                        f">{TOLERANCE:.0%} above baseline {base_p99:.3f}")
            base_prune = metric(base, "prune_rate")
            if base_prune is not None and base_prune > 0:
                cur_prune = metric(cur, "prune_rate") or 0.0
                if cur_prune < base_prune * (1 - TOLERANCE):
                    failures.append(
                        f"{name}: prune_rate {cur_prune:.3f} is "
                        f">{TOLERANCE:.0%} below baseline {base_prune:.3f}")
        for name in sorted(set(cur_rows) - set(base_rows)):
            notes.append(f"{name}: new scenario, not in baseline (unguarded "
                         "until the baseline is refreshed)")

    for note in notes:
        print(f"note: {note}")
    if failures:
        print(f"\nFAIL: {len(failures)} bench regression(s) vs {baseline_path}:")
        for f in failures:
            print(f"  - {f}")
        sys.exit(1)
    print("\nbench regression check passed")


if __name__ == "__main__":
    main()
